//! Integration: the full serving coordinator (client executor → RLC →
//! channel → cloud executor) over real artifacts, plus failure injection.
//! Skips when artifacts are absent.

use std::path::{Path, PathBuf};

use neupart::channel::TransmitEnv;
use neupart::coordinator::{
    Coordinator, CoordinatorConfig, ExecutorBackend, HealthConfig, InferenceRequest, RetryPolicy,
};
use neupart::corpus::Corpus;

fn have_artifacts() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

fn config(network: &str, force: Option<usize>) -> CoordinatorConfig {
    CoordinatorConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        network: network.to_string(),
        env: TransmitEnv::with_effective_rate(130.0e6, 0.78),
        jpeg_quality: 90,
        cloud_pool: 1,
        workers: 2,
        jitter: 0.0,
        time_scale: 0.0,
        force_split: force,
        warm_splits: Vec::new(),
        batch_max: 3,
        gamma_coherent: true,
        shed_infeasible: true,
        backend: ExecutorBackend::Pjrt,
        faults: None,
        scenario: None,
        redecide: None,
        retry: RetryPolicy::default(),
        health: HealthConfig::default(),
        seed: 5,
    }
}

fn requests(n: usize) -> Vec<InferenceRequest> {
    Corpus::new(32, 32, 17)
        .iter(n)
        .enumerate()
        .map(|(i, img)| {
            InferenceRequest::new(i as u64, img.to_f32_nhwc(), img.pixels, img.w, img.h)
        })
        .collect()
}

#[test]
fn serve_roundtrip_and_metrics_consistency() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::new(config("tiny_alexnet", None)).unwrap();
    let n = 6;
    let responses = coord.serve_responses(requests(n)).unwrap();
    assert_eq!(responses.len(), n);
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64, "responses in request order");
        assert_eq!(r.logits.len(), 10);
        assert!(r.logits.iter().all(|v| v.is_finite()));
        assert!(r.split <= 11);
        assert!((0.0..=1.0).contains(&r.sparsity_in));
        assert!(r.e_cost_j() > 0.0);
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.requests, n as u64);
    assert_eq!(m.split_counts.values().sum::<u64>(), n as u64);
    let bits: u64 = responses.iter().map(|r| r.transmit_bits).sum();
    assert_eq!(m.transmit_bits, bits);
    // Worker threads were seeded from the shared compiled profile; the
    // post-warm-up miss counter is the canary that no §IV-C schedule
    // derivation runs on the serving hot path (decisions are table
    // slices — a regression that re-evaluates the model per request on a
    // worker would trip this).
    assert!(m.schedule_seeded > 0, "workers were not profile-seeded");
    assert_eq!(m.schedule_misses_post_warm, 0);
}

#[test]
fn partitioned_inference_agrees_with_cloud() {
    if !have_artifacts() {
        return;
    }
    let n = 5;
    // Cloud-only reference.
    let fcc = Coordinator::new(config("tiny_alexnet", Some(0)))
        .unwrap()
        .serve_responses(requests(n))
        .unwrap();
    // Forced mid-network split: exercises quantize -> RLC -> dequantize.
    let mid = Coordinator::new(config("tiny_alexnet", Some(5)))
        .unwrap()
        .serve_responses(requests(n))
        .unwrap();
    let agree = fcc
        .iter()
        .zip(&mid)
        .filter(|(a, b)| a.top1() == b.top1())
        .count();
    assert!(agree >= n - 1, "only {agree}/{n} top-1 agreement");
    // 8-bit quantization error stays small in L2.
    for (a, b) in fcc.iter().zip(&mid) {
        let ref_norm: f32 = a.logits.iter().map(|v| v * v).sum::<f32>().sqrt();
        let err: f32 = a
            .logits
            .iter()
            .zip(&b.logits)
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f32>()
            .sqrt();
        assert!(err < 0.25 * ref_norm.max(1e-3), "err {err} vs norm {ref_norm}");
    }
}

#[test]
fn forced_fisc_never_touches_channel_payloads() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::new(config("tiny_alexnet", Some(11))).unwrap();
    let responses = coord.serve_responses(requests(3)).unwrap();
    for r in responses {
        assert_eq!(r.split, 11);
        assert!(r.transmit_bits <= 64, "FISC shipped {} bits", r.transmit_bits);
        assert!(r.client_energy_j > 0.0);
    }
}

#[test]
fn channel_jitter_does_not_break_serving() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = config("tiny_squeezenet", None);
    cfg.jitter = 0.3;
    let coord = Coordinator::new(cfg).unwrap();
    let responses = coord.serve_responses(requests(4)).unwrap();
    assert_eq!(responses.len(), 4);
}

#[test]
fn gamma_bucketed_batches_match_per_request_decisions() {
    if !have_artifacts() {
        return;
    }
    // Under per-request channel jitter, γ-coherent admission must choose
    // exactly the splits the unbucketed per-request path chooses: the
    // admission env sampling is seeded, so two runs over the same workload
    // differ only in bucketing.
    let n = 10;
    let mut bucketed_cfg = config("tiny_alexnet", None);
    bucketed_cfg.jitter = 0.4;
    bucketed_cfg.gamma_coherent = true;
    let bucketed = Coordinator::new(bucketed_cfg).unwrap();
    let with_buckets = bucketed.serve_responses(requests(n)).unwrap();

    let mut flat_cfg = config("tiny_alexnet", None);
    flat_cfg.jitter = 0.4;
    flat_cfg.gamma_coherent = false;
    let flat = Coordinator::new(flat_cfg).unwrap();
    let without_buckets = flat.serve_responses(requests(n)).unwrap();

    for (a, b) in with_buckets.iter().zip(&without_buckets) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.split, b.split, "request {}", a.id);
    }
    // The bucketed run recorded segment and batch accounting.
    let m = bucketed.metrics.snapshot();
    assert_eq!(m.requests, n as u64);
    assert!(m.batches >= 1);
    assert_eq!(m.batch_requests, n as u64);
    assert_eq!(m.segment_counts.values().sum::<u64>(), n as u64);
}

#[test]
fn explicit_request_env_steers_the_decision() {
    if !have_artifacts() {
        return;
    }
    // A request reporting a dead-slow channel must stay on the client
    // (FISC) regardless of the coordinator's configured env.
    let coord = Coordinator::new(config("tiny_alexnet", None)).unwrap();
    let mut reqs = requests(2);
    reqs[1].env = Some(TransmitEnv::with_effective_rate(10.0, 0.78)); // 10 bps
    let responses = coord.serve_responses(reqs).unwrap();
    let n_layers = coord.partitioner().num_layers();
    assert_eq!(responses[1].split, n_layers, "dead channel must pin FISC");
}

#[test]
fn corrupted_channel_states_route_to_overflow_lane_without_panicking() {
    if !have_artifacts() {
        return;
    }
    // Regression: a request reporting a NaN/∞/non-positive rate (a
    // corrupted channel-state report) must be admitted into the overflow
    // lane and served through the guarded scan path — never panic in the
    // γ-segment search and never pin to a bogus envelope segment.
    let coord = Coordinator::new(config("tiny_alexnet", None)).unwrap();
    let mut reqs = requests(5);
    reqs[1].env = Some(TransmitEnv::with_effective_rate(f64::NAN, 0.78));
    reqs[2].env = Some(TransmitEnv::with_effective_rate(f64::INFINITY, 0.78));
    reqs[3].env = Some(TransmitEnv::with_effective_rate(-80e6, 0.78));
    // Corrupted transmit power (γ = ∞ at a finite rate).
    reqs[4].env = Some(TransmitEnv::with_effective_rate(80e6, f64::INFINITY));
    let responses = coord.serve_responses(reqs).unwrap();
    assert_eq!(responses.len(), 5);
    for r in &responses {
        if r.id != 0 {
            assert_eq!(r.gamma_segment, None, "request {} got a segment", r.id);
        }
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    // A corrupted state plus a deadline exercises the shedding bound's
    // degenerate-channel guard too (NaN rate → FISC-only lower bound).
    let coord = Coordinator::new(config("tiny_alexnet", None)).unwrap();
    let mut reqs = requests(2);
    reqs[1].env = Some(TransmitEnv::with_effective_rate(f64::NAN, 0.78));
    reqs[1].deadline_s = Some(1e3);
    let responses = coord.serve_responses(reqs).unwrap();
    assert_eq!(responses.len(), 2);
}

#[test]
fn registry_without_slo_engine_is_counted_not_silent() {
    if !have_artifacts() {
        return;
    }
    // A registry populated from a v1-shaped table (no latency data) has no
    // shared SLO engine: the coordinator must rebuild one from the
    // compiled profile AND count the event — deadline serving still works.
    let registry = neupart::partition::PolicyRegistry::new();
    let cfg = config("tiny_alexnet", None);
    let profile = neupart::CnnErgy::inference_8bit()
        .compiled(&neupart::Network::by_name("tiny_alexnet").unwrap());
    let v1_table = neupart::EnvelopeTable::from_partitioner(
        "tiny_alexnet",
        &neupart::partition::device_class(cfg.env.p_tx_w),
        cfg.env.p_tx_w,
        &neupart::Partitioner::from_profile(&profile),
    );
    registry.insert_table(v1_table);
    let coord = Coordinator::with_registry(cfg, &registry).unwrap();
    assert_eq!(coord.metrics.snapshot().slo_missing, 1);
    let mut reqs = requests(2);
    reqs[0].deadline_s = Some(1e3); // loose: must be served
    reqs[1].deadline_s = Some(1e-9); // provably infeasible: must be shed
    let responses = coord.serve_responses(reqs).unwrap();
    assert_eq!(responses.len(), 1);
    let m = coord.metrics.snapshot();
    assert_eq!(m.shed_infeasible, 1);

    // The analytic path (and a v2 import) shares the registry engine: no
    // rebuild, counter stays 0.
    let coord = Coordinator::new(config("tiny_alexnet", None)).unwrap();
    assert_eq!(coord.metrics.snapshot().slo_missing, 0);
}

#[test]
fn infeasible_deadlines_are_shed_at_admission() {
    if !have_artifacts() {
        return;
    }
    let coord = Coordinator::new(config("tiny_alexnet", None)).unwrap();
    let mut reqs = requests(4);
    // Below any conceivable inference delay (the cloud-only compute time
    // alone is orders of magnitude larger): provably infeasible.
    reqs[1].deadline_s = Some(1e-9);
    // Generous deadline: must be served normally.
    reqs[2].deadline_s = Some(1e3);
    let responses = coord.serve_responses(reqs).unwrap();
    let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
    assert_eq!(ids, vec![0, 2, 3], "shed request omitted, order preserved");
    let m = coord.metrics.snapshot();
    assert_eq!(m.shed_infeasible, 1);
    assert_eq!(m.requests, 3);

    // With shedding disabled the same workload is served best-effort.
    let mut cfg = config("tiny_alexnet", None);
    cfg.shed_infeasible = false;
    let coord = Coordinator::new(cfg).unwrap();
    let mut reqs = requests(4);
    reqs[1].deadline_s = Some(1e-9);
    let responses = coord.serve_responses(reqs).unwrap();
    assert_eq!(responses.len(), 4);
    assert_eq!(coord.metrics.snapshot().shed_infeasible, 0);
}

#[test]
fn coordinators_share_one_registry_entry() {
    if !have_artifacts() {
        return;
    }
    // Fleet mode: two connections of the same (network, device P_Tx
    // class) built against one shared registry reuse one decision engine.
    let registry = neupart::partition::PolicyRegistry::new();
    let a = Coordinator::with_registry(config("tiny_alexnet", None), &registry).unwrap();
    let b = Coordinator::with_registry(config("tiny_alexnet", None), &registry).unwrap();
    assert_eq!(registry.len(), 1);
    assert!(
        std::ptr::eq(a.partitioner(), b.partitioner()),
        "engines must be shared through the registry"
    );
    // And the shared engine still serves.
    let responses = a.serve_responses(requests(3)).unwrap();
    assert_eq!(responses.len(), 3);
}

#[test]
fn unknown_network_fails_fast() {
    if !have_artifacts() {
        return;
    }
    assert!(Coordinator::new(config("not_a_net", None)).is_err());
}

#[test]
fn missing_artifacts_fail_fast() {
    let mut cfg = config("tiny_alexnet", None);
    cfg.artifacts_dir = PathBuf::from("/nonexistent");
    assert!(Coordinator::new(cfg).is_err());
}
