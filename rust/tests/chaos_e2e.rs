//! Chaos e2e: the coordinator's failure path under injected faults.
//!
//! Runs on the artifact-free deterministic sim backend
//! (`ExecutorBackend::Sim`), so unlike `serving_e2e` this suite never
//! skips. Each test injects one fault class — transfer drops, stalls,
//! Markov outages, a killed cloud pool, a poisoned request — and asserts
//! the bounded-outcome contract: every admitted request resolves to
//! exactly one of Ok / Degraded / Failed, FISC fallbacks account the
//! energy actually spent, and a fixed fault seed reproduces the schedule
//! bit-for-bit.
//!
//! Set `NEUPART_CHAOS_AGGRESSIVE=1` to scale request counts up 8×.

use std::path::PathBuf;
use std::sync::mpsc::channel;

use neupart::channel::{FaultConfig, MarkovOutage, TransmitEnv};
use neupart::coordinator::{
    Admit, BreakerConfig, Coordinator, CoordinatorConfig, ExecutorBackend, HealthConfig,
    InferenceOutcome, InferenceRequest, RetryPolicy, ServingTier, ServingTierConfig,
};
use neupart::corpus::Corpus;
use neupart::runtime::SIM_POISON;

fn scale(n: usize) -> usize {
    if std::env::var_os("NEUPART_CHAOS_AGGRESSIVE").is_some() {
        n * 8
    } else {
        n
    }
}

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        // Never read by the sim backend.
        artifacts_dir: PathBuf::from("artifacts"),
        network: "tiny_alexnet".to_string(),
        env: TransmitEnv::with_effective_rate(130.0e6, 0.78),
        jpeg_quality: 90,
        cloud_pool: 2,
        workers: 2,
        jitter: 0.0,
        time_scale: 0.0,
        force_split: None,
        warm_splits: Vec::new(),
        batch_max: 3,
        gamma_coherent: true,
        shed_infeasible: true,
        backend: ExecutorBackend::Sim,
        faults: None,
        scenario: None,
        redecide: None,
        retry: RetryPolicy::default(),
        health: HealthConfig::default(),
        seed: 42,
    }
}

/// A health config whose breaker never trips on error rate: the
/// exact-count tests below drive sustained 100% remote failure on
/// purpose, and the counts they assert (retries, outage rejections,
/// deadline abandonments per request) only hold while every request
/// still walks the full remote path.
fn no_breaker() -> HealthConfig {
    HealthConfig {
        breaker: BreakerConfig::disabled(),
        ..HealthConfig::default()
    }
}

/// A health config whose breaker force-opens on a dead pool but never
/// cools down into probing: the degraded-mode tests pin the Open state
/// so trip counts are exact.
fn sticky_breaker() -> HealthConfig {
    HealthConfig {
        breaker: BreakerConfig {
            cooldown_s: 3600.0,
            ..BreakerConfig::default()
        },
        ..HealthConfig::default()
    }
}

/// A two-shard tier over `base`: one shard per Table-IV WLAN class
/// (LG Nexus 4 at 0.78 W, Note 3 at 1.28 W).
fn two_class_tier(base: CoordinatorConfig) -> ServingTier {
    let envs = [
        TransmitEnv::with_effective_rate(130.0e6, 0.78),
        TransmitEnv::with_effective_rate(130.0e6, 1.28),
    ];
    ServingTier::new(ServingTierConfig::per_class(base, &envs)).unwrap()
}

fn requests(n: usize) -> Vec<InferenceRequest> {
    Corpus::new(32, 32, 17)
        .iter(n)
        .enumerate()
        .map(|(i, img)| {
            InferenceRequest::new(i as u64, img.to_f32_nhwc(), img.pixels, img.w, img.h)
        })
        .collect()
}

/// Every outcome resolved, ids in request order, responses sane.
fn assert_resolved(outcomes: &[InferenceOutcome], n: usize) {
    assert_eq!(outcomes.len(), n);
    for (i, o) in outcomes.iter().enumerate() {
        assert_eq!(o.id(), i as u64, "outcomes in request order");
        if let Some(r) = o.response() {
            assert!(!r.logits.is_empty());
            assert!(r.logits.iter().all(|v| v.is_finite()));
            assert!(r.client_energy_j.is_finite() && r.client_energy_j >= 0.0);
            assert!(r.transmit_energy_j.is_finite() && r.transmit_energy_j >= 0.0);
            assert!(r.wasted_energy_j.is_finite() && r.wasted_energy_j >= 0.0);
        }
    }
}

#[test]
fn clean_channel_serves_everything_ok() {
    let n = scale(6);
    let coord = Coordinator::new(config()).unwrap();
    let outcomes = coord.serve(requests(n)).unwrap();
    assert_resolved(&outcomes, n);
    assert!(outcomes.iter().all(InferenceOutcome::is_ok), "clean run degraded");
    let m = coord.metrics.snapshot();
    assert_eq!(m.requests, n as u64);
    assert_eq!(m.retries_total, 0);
    assert_eq!(m.transfers_dropped, 0);
    assert_eq!(m.fallback_fisc, 0);
    assert_eq!(m.failed_requests, 0);
    assert!(!coord.is_degraded());
}

#[test]
fn transfer_drops_are_retried_through() {
    let n = scale(16);
    let mut cfg = config();
    cfg.faults = Some(FaultConfig {
        drop_prob: 0.5,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: None,
        seed: 911,
    });
    cfg.retry = RetryPolicy {
        max_attempts: 8,
        ..RetryPolicy::default()
    };
    let coord = Coordinator::new(cfg).unwrap();
    let outcomes = coord.serve(requests(n)).unwrap();
    assert_resolved(&outcomes, n);
    // Retries absorb the drops: nothing fails, and with 8 attempts at
    // p=0.5 almost everything lands Ok (a straggler may exhaust its
    // budget and complete degraded — that is the contract, not a bug).
    assert!(outcomes.iter().all(|o| !o.is_failed()));
    let ok = outcomes.iter().filter(|o| o.is_ok()).count();
    assert!(ok >= n - n / 8, "only {ok}/{n} recovered via retry");
    let m = coord.metrics.snapshot();
    assert!(m.retries_total > 0, "drops at p=0.5 never triggered a retry");
    assert!(m.transfers_dropped > 0);
    assert!(m.wasted_retry_energy_j > 0.0, "drops wasted no energy");
    // Per-request retry accounting shows up in the responses too.
    let retried: u32 = outcomes
        .iter()
        .filter_map(|o| o.response().map(|r| r.retries))
        .sum();
    assert!(retried > 0);
}

#[test]
fn exhausted_uplink_falls_back_to_fisc_with_energy_accounting() {
    let n = scale(6);
    let mut cfg = config();
    cfg.workers = 1;
    cfg.health = no_breaker();
    cfg.faults = Some(FaultConfig {
        drop_prob: 1.0, // every transfer dies mid-flight
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: None,
        seed: 13,
    });
    cfg.retry = RetryPolicy {
        max_attempts: 2,
        ..RetryPolicy::default()
    };
    let coord = Coordinator::new(cfg).unwrap();
    let n_layers = coord.partitioner().num_layers();
    let outcomes = coord.serve(requests(n)).unwrap();
    assert_resolved(&outcomes, n);
    let mut wasted_sum = 0.0;
    for o in &outcomes {
        assert!(o.is_degraded(), "dead uplink must degrade, got {o:?}");
        let r = o.response().unwrap();
        assert!(r.fallback_fisc);
        assert_eq!(r.split, n_layers, "fallback must run fully in situ");
        assert_eq!(r.transmit_bits, 0, "fallback shipped bits over a dead link");
        assert_eq!(r.transmit_energy_j, 0.0);
        assert!(r.client_energy_j > 0.0, "in-situ run spent no energy?");
        // Exactly one retry then exhaustion, per the 2-attempt policy.
        assert_eq!(r.retries, 1);
        assert!(r.wasted_energy_j > 0.0, "dropped transfers wasted no energy");
        wasted_sum += r.wasted_energy_j;
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.fallback_fisc, n as u64);
    assert_eq!(m.retries_total, n as u64);
    assert_eq!(m.transfers_dropped, 2 * n as u64);
    assert_eq!(m.failed_requests, 0);
    // The per-request waste reconciles with the channel's own books.
    let stats = coord.channel_stats();
    assert_eq!(stats.transfers, 0, "nothing was ever delivered");
    assert_eq!(stats.transfers_dropped, 2 * n as u64);
    let diff = (wasted_sum - stats.wasted_energy_j).abs();
    assert!(
        diff <= 1e-9 * stats.wasted_energy_j.max(1.0),
        "response waste {wasted_sum} != channel waste {}",
        stats.wasted_energy_j
    );
}

#[test]
fn pinned_outage_degrades_without_spending_radio_energy() {
    let n = scale(5);
    let mut cfg = config();
    cfg.health = no_breaker();
    cfg.faults = Some(FaultConfig {
        drop_prob: 0.0,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        // Down on the first Markov step, never recovers.
        outage: Some(MarkovOutage {
            p_up_to_down: 1.0,
            p_down_to_up: 0.0,
        }),
        seed: 5,
    });
    cfg.retry = RetryPolicy {
        max_attempts: 3,
        ..RetryPolicy::default()
    };
    let coord = Coordinator::new(cfg).unwrap();
    let outcomes = coord.serve(requests(n)).unwrap();
    assert_resolved(&outcomes, n);
    for o in &outcomes {
        let r = o.response().expect("outage must degrade, not fail");
        assert!(o.is_degraded());
        assert!(r.fallback_fisc);
        // Outage rejections are fail-fast: no partial transfer, no waste.
        assert_eq!(r.wasted_energy_j, 0.0);
    }
    let m = coord.metrics.snapshot();
    assert!(m.outage_rejections >= n as u64);
    assert_eq!(m.transfers_dropped, 0);
    let stats = coord.channel_stats();
    assert_eq!(stats.transfers, 0);
    assert_eq!(stats.energy_j, 0.0, "outage windows must not burn energy");
}

#[test]
fn killed_cloud_pool_opens_breaker_into_client_only_mode() {
    let n = scale(6);
    let mut cfg = config();
    cfg.force_split = Some(3); // partitioned: every request needs the cloud
    cfg.health = sticky_breaker(); // no cooldown: the Open state is pinned
    let coord = Coordinator::new(cfg).unwrap();
    let n_layers = coord.partitioner().num_layers();

    coord.kill_cloud_pool();
    // Threads drain their shutdown signals and exit.
    let cloud = coord.cloud_handle();
    for _ in 0..500 {
        if cloud.alive_threads() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(cloud.alive_threads(), 0, "killed pool still alive");

    let outcomes = coord.serve(requests(n)).unwrap();
    assert_resolved(&outcomes, n);
    for o in &outcomes {
        assert!(o.is_degraded(), "dead cloud must degrade, got {o:?}");
        let r = o.response().unwrap();
        assert_eq!(r.split, n_layers, "degraded mode must serve client-only");
        assert_eq!(r.decided_split, 3);
    }
    assert!(coord.is_degraded());
    let m = coord.metrics.snapshot();
    assert_eq!(m.degraded_mode_entered, 1, "force-open must trip exactly once");
    assert_eq!(m.fallback_fisc, n as u64);
    assert_eq!(m.failed_requests, 0);

    // With the cooldown pinned, the Open breaker keeps serving
    // client-only without re-tripping or probing.
    let more = coord.serve(requests(3)).unwrap();
    assert!(more.iter().all(InferenceOutcome::is_degraded));
    let m = coord.metrics.snapshot();
    assert_eq!(m.degraded_mode_entered, 1);
    assert_eq!(m.breaker_probes, 0, "pinned cooldown must never probe");
    assert_eq!(m.breaker_reopened, 0);
}

#[test]
fn poisoned_request_fails_alone_and_threads_survive() {
    let n = 5;
    let mut cfg = config();
    cfg.force_split = Some(4); // client prefix sees the poison first
    let coord = Coordinator::new(cfg).unwrap();
    let mut reqs = requests(n);
    reqs[2].tensor[0] = SIM_POISON;
    let outcomes = coord.serve(reqs).unwrap();
    assert_resolved(&outcomes, n);
    for (i, o) in outcomes.iter().enumerate() {
        if i == 2 {
            match o {
                InferenceOutcome::Failed(f) => {
                    assert!(
                        f.error.contains("poison"),
                        "panic cause lost in '{}'",
                        f.error
                    );
                }
                other => panic!("poisoned request resolved as {other:?}"),
            }
        } else {
            assert!(o.is_ok(), "sibling of poisoned request was hit: {o:?}");
        }
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.failed_requests, 1);
    assert_eq!(m.requests, (n - 1) as u64, "only served requests recorded");
    // The executor threads contained the panic: both devices still serve.
    assert_eq!(coord.client_handle().alive_threads(), 1);
    assert_eq!(coord.cloud_handle().alive_threads(), 2);
    let clean = coord.serve(requests(4)).unwrap();
    assert!(clean.iter().all(InferenceOutcome::is_ok));
}

#[test]
fn hopeless_deadline_abandons_retries_but_still_degrades() {
    let n = scale(4);
    let mut cfg = config();
    cfg.shed_infeasible = false; // let the hopeless deadline through
    cfg.health = no_breaker();
    cfg.faults = Some(FaultConfig {
        drop_prob: 1.0,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: None,
        seed: 3,
    });
    cfg.retry = RetryPolicy {
        max_attempts: 10,
        ..RetryPolicy::default()
    };
    let coord = Coordinator::new(cfg).unwrap();
    let mut reqs = requests(n);
    for r in &mut reqs {
        // No backoff + attempt fits in a picosecond: the very first
        // failure must abandon the retry loop on the deadline budget.
        r.deadline_s = Some(1e-12);
    }
    let outcomes = coord.serve(reqs).unwrap();
    assert_resolved(&outcomes, n);
    for o in &outcomes {
        let r = o.response().expect("deadline abandonment must degrade");
        assert!(r.fallback_fisc);
        assert_eq!(r.retries, 0, "budget-dead request still retried");
    }
    let m = coord.metrics.snapshot();
    assert_eq!(m.deadline_abandoned, n as u64);
    assert_eq!(m.retries_total, 0);
}

#[test]
fn seeded_fault_schedule_replays_bit_for_bit() {
    // Single worker + single FIFO lane: request order through the channel
    // is the submission order, so the whole fault schedule is a pure
    // function of the seeds.
    let n = scale(12);
    let build = || {
        let mut cfg = config();
        cfg.workers = 1;
        cfg.gamma_coherent = false;
        cfg.faults = Some(FaultConfig {
            drop_prob: 0.25,
            stall_prob: 0.25,
            stall_max_factor: 2.0,
            outage: None,
            seed: 271_828,
        });
        cfg.retry = RetryPolicy {
            max_attempts: 6,
            ..RetryPolicy::default()
        };
        Coordinator::new(cfg).unwrap()
    };
    let a = build();
    let b = build();
    let out_a = a.serve(requests(n)).unwrap();
    let out_b = b.serve(requests(n)).unwrap();
    assert_resolved(&out_a, n);
    for (x, y) in out_a.iter().zip(&out_b) {
        assert_eq!(x.id(), y.id());
        assert_eq!(x.is_ok(), y.is_ok());
        assert_eq!(x.is_degraded(), y.is_degraded());
        assert_eq!(x.is_failed(), y.is_failed());
        if let (Some(rx), Some(ry)) = (x.response(), y.response()) {
            assert_eq!(rx.split, ry.split);
            assert_eq!(rx.decided_split, ry.decided_split);
            assert_eq!(rx.retries, ry.retries);
            assert_eq!(rx.transmit_bits, ry.transmit_bits);
            assert_eq!(rx.fallback_fisc, ry.fallback_fisc);
            // Bit-for-bit: modeled energies, wasted joules, logits.
            assert_eq!(rx.transmit_energy_j.to_bits(), ry.transmit_energy_j.to_bits());
            assert_eq!(rx.wasted_energy_j.to_bits(), ry.wasted_energy_j.to_bits());
            assert_eq!(rx.logits, ry.logits);
        }
    }
    // The channels walked identical fault schedules.
    assert_eq!(a.channel_stats(), b.channel_stats());
    // And different fault seeds actually diverge (the test has teeth).
    let mut cfg = config();
    cfg.workers = 1;
    cfg.gamma_coherent = false;
    cfg.faults = Some(FaultConfig {
        drop_prob: 0.25,
        stall_prob: 0.25,
        stall_max_factor: 2.0,
        outage: None,
        seed: 161_803,
    });
    cfg.retry = RetryPolicy {
        max_attempts: 6,
        ..RetryPolicy::default()
    };
    let c = Coordinator::new(cfg).unwrap();
    c.serve(requests(n)).unwrap();
    assert_ne!(
        a.channel_stats(),
        c.channel_stats(),
        "different fault seeds produced identical schedules"
    );
}

#[test]
fn process_batch_honors_per_request_channel_states() {
    // Regression (per-request env routing): the batched path used to
    // decide every request at the coordinator's configured env, silently
    // ignoring `req.env`. Two requests at opposite channel extremes must
    // decide differently — and exactly like the per-request path.
    let coord = Coordinator::new(config()).unwrap();
    let n_layers = coord.partitioner().num_layers();
    let client = coord.client_handle();
    let cloud = coord.cloud_handle();
    let mut reqs = requests(2);
    // Blazing free uplink: offloading everything is optimal (FCC).
    reqs[0].env = Some(TransmitEnv::with_effective_rate(1e12, 1e-3));
    // Dead-slow, power-hungry uplink: staying on the client is optimal.
    reqs[1].env = Some(TransmitEnv::with_effective_rate(10.0, 5.0));

    let batch = coord.process_batch(&reqs, &client, &cloud).unwrap();
    assert_eq!(batch.len(), 2);
    let solo: Vec<_> = reqs
        .iter()
        .map(|r| coord.process(r, &client, &cloud).unwrap())
        .collect();
    assert_eq!(batch[0].split, solo[0].split, "batch diverged from solo");
    assert_eq!(batch[1].split, solo[1].split, "batch diverged from solo");
    assert_eq!(batch[0].split, 0, "free uplink must go full cloud");
    assert_eq!(batch[1].split, n_layers, "dead uplink must stay in situ");
    assert_ne!(batch[0].split, batch[1].split);
}

#[test]
fn killed_cloud_pool_degrades_only_its_own_shard() {
    // Shard isolation: a dead cloud pool opens the breaker into
    // client-only mode in its own shard; sibling shards keep serving Ok.
    let n = scale(6);
    let mut base = config();
    base.force_split = Some(3); // partitioned: every request needs the cloud
    base.health = sticky_breaker(); // no cooldown: trip counts are exact
    let tier = two_class_tier(base);
    let victim = &tier.shards()[0];
    victim.kill_cloud_pool();
    let cloud = victim.cloud_handle();
    for _ in 0..500 {
        if cloud.alive_threads() == 0 {
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert_eq!(cloud.alive_threads(), 0, "killed pool still alive");

    // Even ids report the victim's class (0.78 W), odd ids the sibling's.
    let mut reqs = requests(n);
    for (i, r) in reqs.iter_mut().enumerate() {
        let p_tx = if i % 2 == 0 { 0.78 } else { 1.28 };
        r.env = Some(TransmitEnv::with_effective_rate(130.0e6, p_tx));
    }
    let outcomes = tier.serve(reqs).unwrap();
    assert_resolved(&outcomes, n);
    for (i, o) in outcomes.iter().enumerate() {
        if i % 2 == 0 {
            assert!(o.is_degraded(), "dead-cloud shard must degrade, got {o:?}");
        } else {
            assert!(o.is_ok(), "sibling shard hit by a foreign fault: {o:?}");
        }
    }
    assert!(tier.shards()[0].is_degraded());
    assert!(!tier.shards()[1].is_degraded(), "breaker state leaked across shards");
    let fleet = tier.fleet_snapshot();
    assert_eq!(fleet.degraded_mode_entered, 1, "breaker must trip once, in one shard");
    assert_eq!(fleet.fallback_fisc, (n / 2) as u64);
    assert_eq!(fleet.failed_requests, 0);
}

#[test]
fn corrupted_channel_states_use_each_shards_own_overflow_lane() {
    // Per-shard overflow lane: a corrupted channel report (NaN/∞/
    // non-positive rate) routes by its P_Tx class like any other request,
    // then lands in that shard's overflow lane — no panic, no bogus
    // segment pin, and the sibling shard's lanes stay untouched.
    let tier = two_class_tier(config());
    let mut reqs = requests(4);
    reqs[0].env = Some(TransmitEnv::with_effective_rate(f64::NAN, 0.78));
    reqs[1].env = Some(TransmitEnv::with_effective_rate(f64::INFINITY, 1.28));
    reqs[2].env = Some(TransmitEnv::with_effective_rate(-80e6, 0.78));
    reqs[3].env = Some(TransmitEnv::with_effective_rate(0.0, 1.28));
    let outcomes = tier.serve(reqs).unwrap();
    assert_resolved(&outcomes, 4);
    for o in &outcomes {
        let r = o.response().expect("corrupted env must still serve");
        assert_eq!(r.gamma_segment, None, "request {} pinned to a segment", r.id);
    }
    for shard in tier.shards() {
        let m = shard.metrics.snapshot();
        assert_eq!(m.requests, 2);
        let overflow = shard.admission_buckets() - 1;
        assert_eq!(
            m.lane_batches.keys().copied().collect::<Vec<_>>(),
            vec![overflow],
            "corrupted envs must drain only through the overflow lane"
        );
    }
}

#[test]
fn shard_admission_is_fifo_within_a_lane() {
    // With one worker and every request in the same γ lane, outcomes
    // must come back oldest-head-first — the lane is a FIFO, batching
    // and pinning never reorder within it.
    let mut base = config();
    base.workers = 1;
    let tier = two_class_tier(base);
    let shard = &tier.shards()[0];
    let n = scale(8);
    let (tx, rx) = channel();
    for req in requests(n) {
        assert_eq!(shard.admit(req, &tx), Admit::Queued);
    }
    drop(tx);
    let ids: Vec<u64> = rx.iter().map(|o| o.id()).collect();
    assert_eq!(
        ids,
        (0..n as u64).collect::<Vec<_>>(),
        "a γ lane must drain oldest-head-first"
    );
    assert_eq!(shard.metrics.snapshot().requests, n as u64);
    // Direct shard admission never leaks to the sibling.
    assert_eq!(tier.shards()[1].metrics.snapshot().requests, 0);
}

#[test]
fn serve_reassembles_outcomes_by_id_not_position() {
    // Request ids are opaque tokens: serve must pair outcomes with
    // requests by the id each one carried — never by assuming ids are
    // dense, ordered, or index-like.
    let coord = Coordinator::new(config()).unwrap();
    let ids = [100u64, 7, 3000, 42];
    let mut reqs = requests(ids.len());
    for (r, id) in reqs.iter_mut().zip(ids) {
        r.id = id;
    }
    let outcomes = coord.serve(reqs).unwrap();
    let got: Vec<u64> = outcomes.iter().map(|o| o.id()).collect();
    assert_eq!(got, ids, "outcomes must follow admission order keyed by id");
    assert!(outcomes.iter().all(InferenceOutcome::is_ok));
}
