//! Trust-boundary tests for the v3 binary fleet blob, over CHECKED-IN
//! corrupt fixtures (`rust/tests/fixtures/fleet_blob_v3/`): a
//! network-supplied blob that is truncated, bit-flipped, misaligned or
//! version-bumped must be rejected loudly — citing the byte offset at
//! fault — with no panic and no partial import.
//!
//! The fixtures are deterministic: `good.bin` is byte-for-byte
//! `FleetBlob::encode` over [`fixture_table`] (asserted below, so the
//! checked-in bytes can never drift from the encoder), and every corrupt
//! fixture is a documented surgical edit of it. Regenerate with
//! `cargo test --test fleet_blob_v3 regenerate_fixtures -- --ignored`.

use neupart::partition::{
    DelayTables, EnvelopeTable, FleetBlob, LazyFleet, PolicyRegistry, FLEET_BLOB_MAGIC,
    FLEET_BLOB_VERSION,
};

const GOOD: &[u8] = include_bytes!("fixtures/fleet_blob_v3/good.bin");
const TRUNCATED: &[u8] = include_bytes!("fixtures/fleet_blob_v3/truncated.bin");
const BITFLIP: &[u8] = include_bytes!("fixtures/fleet_blob_v3/bitflip.bin");
const MISALIGNED: &[u8] = include_bytes!("fixtures/fleet_blob_v3/misaligned.bin");
const WRONG_VERSION: &[u8] = include_bytes!("fixtures/fleet_blob_v3/wrong_version.bin");

/// The fixture fleet: one entry with exact-representable values (struct
/// literal, not an engine build, so the bytes are trivially stable).
fn fixture_table() -> EnvelopeTable {
    EnvelopeTable {
        network: "fixnet".to_string(),
        device: "ptx-0.750W".to_string(),
        p_tx_w: 0.75,
        bw: 8,
        input_raw_bits: 1_000_000,
        cumulative_energy_j: vec![0.125, 0.25, 0.5, 1.0],
        d_rlc_bits: vec![1024.0, 512.0, 64.0, 32.0],
        breakpoints: vec![0.0009765625, 0.03125],
        segment_splits: vec![4, 2, 1],
        delay: Some(DelayTables {
            client_latencies_s: vec![0.001, 0.002, 0.004, 0.008],
            cloud_latencies_s: vec![0.0001, 0.0002, 0.0004, 0.0008],
        }),
    }
}

fn open_err(bytes: &[u8]) -> String {
    match FleetBlob::open(bytes.to_vec()) {
        Ok(_) => panic!("corrupt blob must be rejected"),
        Err(e) => e.to_string(),
    }
}

#[test]
fn good_fixture_matches_the_encoder_and_round_trips() {
    // The checked-in bytes ARE the encoder's output — fixture drift is a
    // test failure, not a silent skew.
    assert_eq!(
        GOOD,
        FleetBlob::encode([&fixture_table()]).as_slice(),
        "good.bin no longer matches FleetBlob::encode (regenerate fixtures)"
    );
    let blob = FleetBlob::open(GOOD.to_vec()).expect("good fixture must open");
    assert_eq!(blob.len(), 1);
    assert_eq!(
        blob.entry_key(0).unwrap(),
        ("fixnet".to_string(), "ptx-0.750W".to_string())
    );
    assert_eq!(blob.entry(0).unwrap(), fixture_table());
    assert_eq!(blob.find("fixnet", "ptx-0.750W").unwrap(), Some(0));
    assert_eq!(blob.find("fixnet", "no-such-class").unwrap(), None);
    assert_eq!(&GOOD[0..4], &FLEET_BLOB_MAGIC);
    assert_eq!(
        u32::from_le_bytes(GOOD[4..8].try_into().unwrap()),
        FLEET_BLOB_VERSION
    );
}

#[test]
fn truncated_blob_is_rejected_with_cited_size() {
    // good.bin cut to 40 bytes: not even a full header.
    let err = open_err(TRUNCATED);
    assert!(err.contains("truncated"), "unexpected error: {err}");
    assert!(err.contains("40 bytes"), "unexpected error: {err}");

    // Cut past the header instead: the header's total-length field gives
    // the truncation away before any entry is touched.
    let err = open_err(&GOOD[..GOOD.len() - 8]);
    assert!(err.contains("length mismatch"), "unexpected error: {err}");
    assert!(err.contains("offset 16"), "unexpected error: {err}");
}

#[test]
fn bit_flipped_payload_is_rejected_by_the_checksum() {
    // good.bin with one bit flipped inside an f64 lane.
    let err = open_err(BITFLIP);
    assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
    assert!(err.contains("offset 24"), "unexpected error: {err}");

    // Any payload byte is covered — flip the last one too.
    let mut blob = GOOD.to_vec();
    let last = blob.len() - 1;
    blob[last] ^= 0x80;
    let err = open_err(&blob);
    assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
}

#[test]
fn misaligned_entry_offset_is_rejected() {
    // good.bin with entry 0's offset nudged to 84 (not 8-byte aligned)
    // and the checksum re-patched, so the alignment check itself fires.
    let err = open_err(MISALIGNED);
    assert!(err.contains("misaligned entry 0"), "unexpected error: {err}");
    assert!(err.contains("offset 84"), "unexpected error: {err}");
}

#[test]
fn wrong_version_is_rejected_before_the_checksum() {
    // good.bin with the version field set to 9: rejected by its own
    // targeted message (the header is deliberately outside the checksum).
    let err = open_err(WRONG_VERSION);
    assert!(err.contains("unsupported version 9"), "unexpected error: {err}");
    assert!(err.contains("offset 4"), "unexpected error: {err}");

    // Bad magic likewise.
    let mut blob = GOOD.to_vec();
    blob[0] = b'X';
    let err = open_err(&blob);
    assert!(err.contains("bad magic"), "unexpected error: {err}");
    assert!(err.contains("offset 0"), "unexpected error: {err}");
}

#[test]
fn corrupt_blobs_never_partially_import() {
    for corrupt in [TRUNCATED, BITFLIP, MISALIGNED, WRONG_VERSION] {
        let registry = PolicyRegistry::new();
        assert!(registry.import_v3(corrupt).is_err());
        assert!(
            registry.is_empty(),
            "a rejected blob must import zero entries"
        );
        assert!(LazyFleet::boot(corrupt.to_vec()).is_err());
    }
}

#[test]
fn hostile_entry_header_cannot_overallocate() {
    // Blow up entry 0's n_layers to u64::MAX (and re-patch the checksum
    // so the structural check is what fires): the size check runs in
    // wide arithmetic BEFORE any lane allocation, so the open blob
    // rejects the entry instead of attempting a ~10¹⁹-element Vec.
    let mut blob = GOOD.to_vec();
    let entry_at = 80; // header (64) + one offsets record (16)
    blob[entry_at + 32..entry_at + 40].copy_from_slice(&u64::MAX.to_le_bytes());
    patch_checksum(&mut blob);
    let opened = FleetBlob::open(blob).expect("structurally the spans still parse");
    let err = match opened.entry(0) {
        Ok(_) => panic!("hostile header must fail"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("entry 0"), "unexpected error: {err}");
    assert!(err.contains("header describes"), "unexpected error: {err}");
    // The keyed lookup path hits the same wall, loudly, without panic.
    assert!(opened.find("fixnet", "ptx-0.750W").is_err());
}

fn patch_checksum(blob: &mut [u8]) {
    let sum = neupart::partition::blob::payload_checksum(blob);
    blob[24..32].copy_from_slice(&sum.to_le_bytes());
}

/// Regenerate every fixture under `rust/tests/fixtures/fleet_blob_v3/`.
/// Deterministic: same table literal → same bytes. Run from the repo
/// root (cargo's default test CWD).
#[test]
#[ignore = "writes fixtures; run manually after a layout change"]
fn regenerate_fixtures() {
    let dir = std::path::Path::new("rust/tests/fixtures/fleet_blob_v3");
    std::fs::create_dir_all(dir).unwrap();
    let good = FleetBlob::encode([&fixture_table()]);

    let mut truncated = good.clone();
    truncated.truncate(40);

    let mut bitflip = good.clone();
    // One bit inside the first f64 lane (entry at 80, lanes begin after
    // the 56-byte entry header + 16 padded name bytes).
    bitflip[80 + 56 + 16] ^= 0x01;

    let mut misaligned = good.clone();
    // Entry 0's offset lives at byte 64; 84 breaks 8-byte alignment.
    misaligned[64..72].copy_from_slice(&84u64.to_le_bytes());
    patch_checksum(&mut misaligned);

    let mut wrong_version = good.clone();
    wrong_version[4..8].copy_from_slice(&9u32.to_le_bytes());

    for (name, bytes) in [
        ("good.bin", &good),
        ("truncated.bin", &truncated),
        ("bitflip.bin", &bitflip),
        ("misaligned.bin", &misaligned),
        ("wrong_version.bin", &wrong_version),
    ] {
        std::fs::write(dir.join(name), bytes).unwrap();
    }
}
