//! Scenario e2e: dynamic channels and mid-flight re-decision.
//!
//! Runs on the artifact-free deterministic sim backend
//! (`ExecutorBackend::Sim`). Exercises the `channel::scenario` subsystem
//! end to end through the coordinator: the checked-in trace fixtures
//! parse and validate, a monotone fade shows up in the per-response
//! `gamma_at_admission`/`gamma_at_completion` instrumentation, a link
//! that dies mid-prefix makes the re-deciding executor move the split
//! and beat its frozen-γ twin on accounted energy, and an adversarial
//! γ oscillation is absorbed by the hysteresis band while a margin-0
//! naive twin thrashes.
//!
//! The acceptance scenarios are constructed from the *measured* envelope
//! of the sim `tiny_alexnet` profile (breakpoints, segment winners,
//! layer latencies), not from hard-coded constants, so they stay valid
//! if the energy model is retuned.

use std::path::{Path, PathBuf};

use neupart::channel::{ScenarioConfig, ScenarioModel, TracePoint, TraceScenario, TransmitEnv};
use neupart::compress::jpeg::compress_rgb;
use neupart::coordinator::{
    Coordinator, CoordinatorConfig, ExecutorBackend, HealthConfig, InferenceRequest,
    RedecideConfig, RetryPolicy,
};
use neupart::corpus::Corpus;
use neupart::partition::{DelayModel, Partitioner};

const LTE_FIXTURE: &str = "rust/tests/fixtures/trace_lte_walk.csv";
const WIFI_FIXTURE: &str = "rust/tests/fixtures/trace_wifi_office.csv";

/// Transmit power shared by every scenario in this suite (LTE uplink).
const P_TX_W: f64 = 1.2;

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        // Never read by the sim backend.
        artifacts_dir: PathBuf::from("artifacts"),
        network: "tiny_alexnet".to_string(),
        env: TransmitEnv::with_effective_rate(130.0e6, P_TX_W),
        jpeg_quality: 90,
        cloud_pool: 2,
        workers: 2,
        jitter: 0.0,
        time_scale: 0.0,
        force_split: None,
        warm_splits: Vec::new(),
        batch_max: 3,
        gamma_coherent: true,
        shed_infeasible: true,
        backend: ExecutorBackend::Sim,
        faults: None,
        scenario: None,
        redecide: None,
        retry: RetryPolicy::default(),
        health: HealthConfig::default(),
        seed: 42,
    }
}

fn env_at_gamma(gamma: f64) -> TransmitEnv {
    TransmitEnv::with_effective_rate(P_TX_W / gamma, P_TX_W)
}

/// Deterministic full-range noise pixels: JPEG entropy coding cannot
/// squeeze noise, so the probe volume scales with the pixel count.
fn noise_pixels(dim: usize, seed: u64) -> Vec<f64> {
    let mut state = seed | 1;
    (0..dim * dim * 3)
        .map(|_| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((state >> 33) & 0xff) as f64
        })
        .collect()
}

/// The smallest noise image whose measured JPEG probe makes the FCC line
/// lose to the admission-segment winner `w_lo` at `gamma_adm` with a
/// 1.5× margin — the same `candidate_cost_j` expression the decision
/// path re-evaluates, so the admission decision is pinned to `w_lo`
/// and the mid-flight walk is reached.
fn calibrated_noise(pt: &Partitioner, w_lo: usize, gamma_adm: f64) -> (Vec<f64>, usize) {
    let env = env_at_gamma(gamma_adm);
    for dim in [192usize, 384, 768] {
        let pixels = noise_pixels(dim, 0xC0FFEE);
        let probe = compress_rgb(&pixels, dim, dim, 90).bits as f64;
        if pt.candidate_cost_j(0, probe, &env) > 1.5 * pt.candidate_cost_j(w_lo, probe, &env) {
            return (pixels, dim);
        }
    }
    panic!("no probe large enough to exclude FCC at gamma = {gamma_adm:e}");
}

/// A request carrying the sim tensor (the 32×32 corpus image the sim
/// network runs on) but probing `pixels` — the probe volume and the
/// compute input are independent, which is exactly what lets the tests
/// pin the admission decision.
fn noise_request(id: u64, pixels: Vec<f64>, dim: usize) -> InferenceRequest {
    let img = Corpus::new(32, 32, 17).iter(1).next().expect("corpus image");
    InferenceRequest::new(id, img.to_f32_nhwc(), pixels, dim, dim)
}

#[test]
fn trace_fixtures_parse_and_reject_malformed_rows() {
    let lte = TraceScenario::load(Path::new(LTE_FIXTURE)).unwrap();
    assert_eq!(lte.points().len(), 7);
    assert_eq!(lte.duration_s(), 30.0);
    assert_eq!(lte.max_rate_bps(), 80.0e6);
    assert!(lte.points().iter().all(|p| p.p_tx_w == 1.2));

    let wifi = TraceScenario::load(Path::new(WIFI_FIXTURE)).unwrap();
    assert_eq!(wifi.points().len(), 9);
    assert_eq!(wifi.max_rate_bps(), 120.0e6);
    assert!(wifi.points().iter().all(|p| p.p_tx_w == 0.78));
    // The office trace oscillates between idle and busy every sample.
    for (i, p) in wifi.points().iter().enumerate() {
        let expect = if i % 2 == 0 { 120.0e6 } else { 40.0e6 };
        assert_eq!(p.rate_bps, expect, "wifi sample {i}");
    }

    // The parser is a trust boundary on the fixture format: malformed
    // rows fail loudly with their 1-based line number.
    let err = TraceScenario::parse_csv("# hdr\n0.0,80e6,1.2\n4.0,fast,1.2\n")
        .unwrap_err()
        .to_string();
    assert!(err.contains("line 3"), "{err}");
    let err = TraceScenario::parse_csv("0.0,80e6,1.2\n0.0,40e6,1.2\n").unwrap_err().to_string();
    assert!(err.contains("line 2"), "{err}");
    let err = TraceScenario::load(Path::new("rust/tests/fixtures/no_such_trace.csv"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("no_such_trace.csv"), "{err}");
}

#[test]
fn lte_fixture_fade_raises_completion_gamma() {
    let trace = TraceScenario::load(Path::new(LTE_FIXTURE)).unwrap();
    // Monotone fade: γ strictly rises across the whole recorded range.
    let g: Vec<f64> = (0..=30).map(|t| trace.gamma_at(f64::from(t))).collect();
    assert!(g.windows(2).all(|w| w[0] < w[1]), "fixture γ not monotone: {g:?}");

    let mut cfg = config();
    cfg.scenario = Some(ScenarioConfig::Trace(trace));
    let coord = Coordinator::new(cfg).unwrap();
    let reqs: Vec<InferenceRequest> = Corpus::new(32, 32, 11)
        .iter(2)
        .enumerate()
        .map(|(i, img)| {
            InferenceRequest::new(i as u64, img.to_f32_nhwc(), img.pixels, img.w, img.h)
        })
        .collect();
    let responses = coord.serve_responses(reqs).unwrap();
    assert_eq!(responses.len(), 2);
    for r in &responses {
        assert!(r.gamma_at_admission.is_finite() && r.gamma_at_admission > 0.0);
        // Compute and airtime advance the scenario clock, so on a
        // monotone fade the uplink always completes at a worse γ than
        // it was admitted with.
        assert!(
            r.gamma_at_completion > r.gamma_at_admission,
            "monotone fade must raise γ by completion: {} -> {}",
            r.gamma_at_admission,
            r.gamma_at_completion
        );
    }
}

#[test]
fn fading_link_redecides_and_beats_frozen_gamma() {
    let probe = Coordinator::new(config()).unwrap();
    let pt = probe.partitioner();
    let bps = pt.envelope().breakpoints().to_vec();
    assert!(!bps.is_empty(), "tiny_alexnet envelope has no breakpoints");
    let w_lo = pt.envelope().segments()[0].split;
    let n = pt.num_layers();
    assert!(w_lo < n, "first envelope winner must be an intermediate split");
    let lat = DelayModel::from_profile(probe.profile()).client_latencies_s().to_vec();
    assert!(lat.iter().all(|&t| t.is_finite() && t > 0.0), "degenerate latencies");

    // Admit inside the first envelope segment; the link then dies before
    // the first layer boundary. 1 bps is far below the channel's 1 kbps
    // effective floor, so γ lands beyond every breakpoint and the only
    // plan whose payload can still be shipped cheaply is FISC.
    let gamma_adm = bps[0] / 1.3;
    let (pixels, dim) = calibrated_noise(pt, w_lo, gamma_adm);
    let trace = TraceScenario::from_points(vec![
        TracePoint {
            t_s: 0.0,
            rate_bps: P_TX_W / gamma_adm,
            p_tx_w: P_TX_W,
        },
        TracePoint {
            t_s: lat[0] * 0.5,
            rate_bps: 1.0,
            p_tx_w: P_TX_W,
        },
    ])
    .unwrap();

    let serve = |redecide: Option<RedecideConfig>| {
        let mut cfg = config();
        cfg.scenario = Some(ScenarioConfig::Trace(trace.clone()));
        cfg.redecide = redecide;
        let coord = Coordinator::new(cfg).unwrap();
        let resp = coord
            .serve_responses(vec![noise_request(0, pixels.clone(), dim)])
            .unwrap()
            .remove(0);
        (resp, coord.metrics.snapshot())
    };

    let (moved, m_moved) = serve(Some(RedecideConfig { hysteresis_margin: 0.1 }));
    let (frozen, m_frozen) = serve(None);

    // Both twins admitted the same plan at the same γ...
    assert_eq!(moved.decided_split, w_lo, "admission winner");
    assert_eq!(frozen.decided_split, w_lo, "frozen twin admission winner");
    assert_eq!(frozen.split, w_lo, "frozen twin must keep the admission plan");
    // ...but the re-deciding executor noticed the fade between layers
    // and finished fully in situ instead of uploading into a dead link.
    assert_eq!(moved.split, n, "dead link must re-decide to FISC");
    assert!(m_moved.redecisions_fired >= 1, "no re-decision fired");
    assert_eq!(m_frozen.redecisions_fired, 0);
    assert!(
        m_moved.energy_delta_vs_frozen_j > 0.0,
        "re-decision must model an energy win over frozen γ, got {}",
        m_moved.energy_delta_vs_frozen_j
    );
    // The accounted energy of the executed plan is strictly below the
    // frozen-γ twin's, same seed, same trace: the twin ships a full
    // activation over the floored dead link.
    assert!(
        moved.e_cost_j() < frozen.e_cost_j(),
        "re-decided execution must beat frozen γ: {} vs {} J",
        moved.e_cost_j(),
        frozen.e_cost_j()
    );
    // γ drift instrumentation on both twins.
    assert!(moved.gamma_at_completion > moved.gamma_at_admission);
    assert!(frozen.gamma_at_completion > frozen.gamma_at_admission);
}

#[test]
fn hysteresis_pins_split_while_naive_twin_thrashes() {
    let probe = Coordinator::new(config()).unwrap();
    let pt = probe.partitioner();
    let bps = pt.envelope().breakpoints().to_vec();
    assert!(!bps.is_empty(), "tiny_alexnet envelope has no breakpoints");
    let winners: Vec<usize> = pt.envelope().segments().iter().map(|s| s.split).collect();
    let (w_lo, w1) = (winners[0], winners[1]);
    let n = pt.num_layers();
    assert!(w1 > w_lo, "segment winners must grow with γ");
    assert!(w_lo + 1 < n, "degenerate envelope: first winner {w_lo} of {n} layers");
    let lat = DelayModel::from_profile(probe.profile()).client_latencies_s().to_vec();
    let cum: Vec<f64> = (0..=n).map(|k| lat[..k].iter().sum()).collect();

    let gamma_adm = bps[0] / 1.3;
    // Oscillation peak: past the first boundary (a margin-0 walk clears
    // it) but inside both the 1.5× hysteresis band and segment 1.
    let gamma_osc = if bps.len() >= 2 {
        (bps[0] * 1.3).min((bps[0] * bps[1]).sqrt())
    } else {
        bps[0] * 1.3
    };
    assert!(gamma_osc > bps[0] && gamma_osc < bps[0] * 1.5);

    // Third plateau, reached only after the naive twin's first move: a γ
    // that forces a *second* move. If the first move landed on FISC,
    // drop γ until some shorter still-reachable split beats FISC;
    // otherwise kill the link so FISC wins outright.
    let gamma_c = if w1 == n {
        let mut g = bps[0] / 1e3;
        for _ in 0..8 {
            let env_c = env_at_gamma(g);
            let fisc = pt.candidate_cost_j(n, 0.0, &env_c);
            if (w_lo + 1..n).any(|s| pt.candidate_cost_j(s, 0.0, &env_c) < fisc) {
                break;
            }
            g /= 1e3;
        }
        g
    } else {
        P_TX_W / 1.0
    };

    // Piecewise-constant plateaus timed on the layer-boundary checks:
    // admission and every check through layer w_lo see the oscillation
    // peak band, the check after layer w_lo+1 sees the third plateau.
    let m1 = cum[1] * 0.5;
    let m2 = (cum[w_lo] + cum[w_lo + 1]) * 0.5;
    let h = 0.125 * lat[0].min(lat[w_lo]);
    let plateau = |t_s: f64, gamma: f64| TracePoint {
        t_s,
        rate_bps: P_TX_W / gamma,
        p_tx_w: P_TX_W,
    };
    let trace = TraceScenario::from_points(vec![
        plateau(0.0, gamma_adm),
        plateau(m1 - h, gamma_adm),
        plateau(m1 + h, gamma_osc),
        plateau(m2 - h, gamma_osc),
        plateau(m2 + h, gamma_c),
    ])
    .unwrap();

    let (pixels, dim) = calibrated_noise(pt, w_lo, gamma_adm);
    let serve = |margin: f64| {
        let mut cfg = config();
        cfg.scenario = Some(ScenarioConfig::Trace(trace.clone()));
        cfg.redecide = Some(RedecideConfig { hysteresis_margin: margin });
        let coord = Coordinator::new(cfg).unwrap();
        let resp = coord
            .serve_responses(vec![noise_request(0, pixels.clone(), dim)])
            .unwrap()
            .remove(0);
        (resp, coord.metrics.snapshot())
    };

    // Margin 0.5: the oscillation stays inside the hysteresis band, so
    // every crossing is observed but suppressed and the split is pinned.
    let (pinned, m_pinned) = serve(0.5);
    assert_eq!(pinned.decided_split, w_lo);
    assert_eq!(pinned.split, w_lo, "hysteresis must pin the admission split");
    assert_eq!(m_pinned.redecisions_fired, 0, "hysteresis twin migrated");
    assert!(m_pinned.redecisions_suppressed >= 1, "no suppressed crossing recorded");
    assert_eq!(m_pinned.energy_delta_vs_frozen_j, 0.0);

    // Margin 0: the naive twin chases every crossing and migrates at
    // least twice on the same trace.
    let (thrashed, m_naive) = serve(0.0);
    assert_eq!(thrashed.decided_split, w_lo);
    assert_ne!(thrashed.split, w_lo, "naive twin never moved");
    assert!(
        m_naive.redecisions_fired >= 2,
        "naive twin must thrash (≥2 migrations), fired {}",
        m_naive.redecisions_fired
    );
}
