//! Integration: the PJRT runtime over the real AOT artifacts.
//!
//! Requires `make artifacts`; every test skips silently when artifacts are
//! absent so a fresh checkout stays green, and the full pipeline is
//! exercised by `make test`.

use std::path::Path;

use neupart::corpus::Corpus;
use neupart::runtime::NetworkRuntime;

fn artifacts() -> Option<&'static Path> {
    let dir = Path::new("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

fn image(seed: u64) -> Vec<f32> {
    Corpus::new(32, 32, seed).image(0).to_f32_nhwc()
}

#[test]
fn prefix_suffix_composition_equals_full_network() {
    let Some(dir) = artifacts() else { return };
    for name in ["tiny_alexnet", "tiny_squeezenet"] {
        let rt = NetworkRuntime::load(dir, name).unwrap();
        let img = image(1);
        let full = rt.run_suffix(0, &img).unwrap();
        assert_eq!(full.len(), 10, "{name}: logits length");
        // Every split must compose losslessly (f32 exactness modulo
        // XLA re-association: allow tiny tolerance).
        for split in 1..rt.num_layers() {
            let act = rt.run_prefix(split, &img).unwrap();
            assert_eq!(
                act.len(),
                rt.spec.layers[split - 1].out_elems(),
                "{name} split {split}: activation shape"
            );
            let out = rt.run_suffix(split, &act).unwrap();
            for (a, b) in out.iter().zip(&full) {
                assert!(
                    (a - b).abs() < 1e-4 + 1e-3 * b.abs(),
                    "{name} split {split}: {a} vs {b}"
                );
            }
        }
        // The full prefix is the whole network too.
        let logits = rt.run_prefix(rt.num_layers(), &img).unwrap();
        for (a, b) in logits.iter().zip(&full) {
            assert!((a - b).abs() < 1e-4, "{name} FISC: {a} vs {b}");
        }
    }
}

#[test]
fn relu_layers_produce_sparse_nonneg_activations() {
    let Some(dir) = artifacts() else { return };
    let rt = NetworkRuntime::load(dir, "tiny_alexnet").unwrap();
    let img = image(2);
    // C1 output (split 1): post-ReLU, should be nonnegative and sparse.
    let act = rt.run_prefix(1, &img).unwrap();
    assert!(act.iter().all(|&v| v >= 0.0));
    let sparsity = act.iter().filter(|&&v| v == 0.0).count() as f64 / act.len() as f64;
    assert!(
        (0.15..0.95).contains(&sparsity),
        "C1 sparsity {sparsity} outside ReLU-plausible band"
    );
}

#[test]
fn measured_sparsity_matches_fig10_property() {
    // The paper's Fig. 10 observation on *live* executions: per-layer
    // sparsity has σ ≪ μ across images.
    let Some(dir) = artifacts() else { return };
    let stats =
        neupart::experiments::fig10::measure_tiny(dir, "tiny_squeezenet", 6).unwrap();
    for (name, mu, sigma) in &stats {
        if name.starts_with("Fs") || name.starts_with("Fe") || name.starts_with('C') {
            assert!(*mu > 0.05, "{name}: mu {mu}");
            assert!(sigma < mu, "{name}: sigma {sigma} vs mu {mu}");
        }
    }
}

#[test]
fn deterministic_execution() {
    let Some(dir) = artifacts() else { return };
    let rt = NetworkRuntime::load(dir, "tiny_alexnet").unwrap();
    let img = image(3);
    let a = rt.run_suffix(0, &img).unwrap();
    let b = rt.run_suffix(0, &img).unwrap();
    assert_eq!(a, b);
}

#[test]
fn bad_input_shape_is_an_error_not_a_crash() {
    let Some(dir) = artifacts() else { return };
    let rt = NetworkRuntime::load(dir, "tiny_alexnet").unwrap();
    assert!(rt.run_suffix(0, &[0.0f32; 7]).is_err());
}

#[test]
fn unknown_network_rejected() {
    let Some(dir) = artifacts() else { return };
    assert!(NetworkRuntime::load(dir, "resnet152").is_err());
}

#[test]
fn missing_artifact_dir_rejected() {
    assert!(NetworkRuntime::load(Path::new("/nonexistent/artifacts"), "tiny_alexnet").is_err());
}
