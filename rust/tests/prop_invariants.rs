//! Randomized property tests (in-tree substitute for proptest — the
//! offline build has no external crates; see DESIGN.md §"Offline
//! substitutions"). Each property runs a few hundred seeded random cases
//! and reports the failing case on assertion failure.

use neupart::channel::TransmitEnv;
use neupart::cnn::{ConvShape, Network};
use neupart::cnnergy::{schedule, CnnErgy, HwConfig, NetworkProfile};
use neupart::compress::rlc;
use neupart::partition::{
    decide_with_slo_scan, BatchLanes, Decision, DecisionContext, DelayModel, EnergyPolicy,
    EnvelopeTable, FleetBlob, PartitionPolicy, Partitioner, PolicyRegistry, SloPartitioner,
    SloPolicy, SparsityEnvelopePolicy,
};
use neupart::util::json;
use neupart::util::rng::Rng;

const CASES: usize = 300;

/// Random-but-valid conv shape.
fn random_shape(rng: &mut Rng) -> ConvShape {
    let r = *rng.choose(&[1usize, 3, 5, 7, 11]);
    let u = *rng.choose(&[1usize, 1, 1, 2, 4]);
    let e = rng.range_usize(1, 64);
    let h = (e - 1) * u + r;
    let c = rng.range_usize(1, 512);
    let f = rng.range_usize(1, 512);
    ConvShape::conv(h, h, r, c, f, u)
}

fn random_hw(rng: &mut Rng) -> HwConfig {
    let mut hw = HwConfig::eyeriss();
    hw.j = rng.range_usize(4, 32);
    hw.k = rng.range_usize(4, 32);
    hw.i_s = rng.range_usize(4, 48);
    hw.f_s = rng.range_usize(hw.i_s, 512);
    hw.p_s = rng.range_usize(4, 64);
    hw.glb_bytes = rng.range_usize(4, 512) * 1024;
    hw.batch = rng.range_usize(1, 8);
    hw
}

/// Reference linear scan: `EnergyPolicy::decide_detailed` from a sparsity
/// (the brute-force O(|L|) semantics every fast path must reproduce).
fn reference_scan(policy: &EnergyPolicy, sp: f64, env: &TransmitEnv) -> Decision {
    let ctx = DecisionContext::from_sparsity(policy.partitioner(), sp, *env);
    policy.decide_detailed(&ctx)
}

/// Envelope fast path from a sparsity.
fn fast_decide(policy: &EnergyPolicy, sp: f64, env: &TransmitEnv) -> Decision {
    let ctx = DecisionContext::from_sparsity(policy.partitioner(), sp, *env);
    policy.decide(&ctx)
}

#[test]
fn prop_schedule_invariants() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let hw = random_hw(&mut rng);
        let sch = schedule(&shape, &hw);
        let ctx = format!("case {case}: {shape:?} {hw:?} -> {sch:?}");

        assert!(sch.z_i >= 1 && sch.z_i <= shape.c, "z_i: {ctx}");
        assert!(
            sch.f_i >= 1 && sch.f_i <= shape.f.min(hw.p_s),
            "f_i: {ctx}"
        );
        assert!(sch.y_o >= 1 && sch.y_o <= hw.k.min(shape.e), "y_o: {ctx}");
        assert_eq!(sch.y_i, (sch.y_o - 1) * shape.u + shape.r, "y_i: {ctx}");
        assert!(sch.x_o >= 1 && sch.x_o <= shape.g, "x_o: {ctx}");
        assert_eq!(sch.x_i, (sch.x_o - 1) * shape.u + shape.s, "x_i: {ctx}");
        assert!(sch.yy_o >= sch.y_o && sch.yy_o <= shape.e, "yy_o: {ctx}");
        assert!(sch.n >= 1 && sch.n <= hw.batch, "n: {ctx}");
        // GLB capacity must hold whenever the mapper had room to shrink.
        if sch.x_o > 1 || sch.f_i > 1 || sch.yy_o > sch.y_o {
            assert!(
                sch.ifmap_bytes(&hw) + sch.psum_bytes(&hw) <= hw.glb_bytes as f64,
                "GLB: {ctx}"
            );
        }
        // The pass structure must cover the whole ofmap volume.
        let covered = sch.passes_z(shape.c) as usize * sch.z_i;
        assert!(covered >= shape.c, "z coverage: {ctx}");
    }
}

#[test]
fn prop_rlc_round_trip() {
    let mut rng = Rng::new(0xBEEF);
    for case in 0..CASES {
        let bw = *rng.choose(&[4u32, 8, 12, 16]);
        let n = rng.range_usize(0, 5000);
        let sparsity = rng.next_f64();
        let max = (1u64 << bw) - 1;
        let data: Vec<u16> = (0..n)
            .map(|_| {
                if rng.next_f64() < sparsity {
                    0
                } else {
                    rng.range_u64(1, max) as u16
                }
            })
            .collect();
        let enc = rlc::encode(&data, bw);
        let dec = rlc::decode(&enc, bw);
        assert_eq!(dec, data, "case {case}: bw={bw} n={n} sp={sparsity:.2}");
        // Encoded size is positive iff there is data.
        assert_eq!(enc.len_bits() == 0, n == 0, "case {case}");
    }
}

#[test]
fn prop_partitioner_argmin_matches_brute_force() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..CASES {
        let n_layers = rng.range_usize(1, 30);
        // Random monotone cumulative energy and random transmit volumes.
        let mut cum = Vec::with_capacity(n_layers);
        let mut acc = 0.0;
        for _ in 0..n_layers {
            acc += rng.next_f64() * 1e-3;
            cum.push(acc);
        }
        let d_rlc: Vec<f64> = (0..n_layers)
            .map(|_| rng.next_f64() * 1e6 + 1.0)
            .collect();
        let policy = EnergyPolicy::new(Partitioner::from_parts(cum, d_rlc, 1_000_000, 8));
        let env = TransmitEnv::with_effective_rate(
            rng.next_f64() * 200e6 + 1e6,
            rng.next_f64() * 2.0 + 0.1,
        );
        let sp = rng.next_f64();
        let d = reference_scan(&policy, sp, &env);

        assert_eq!(d.costs_j.len(), n_layers + 1, "case {case}");
        let brute = d
            .costs_j
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(d.l_opt, brute, "case {case}");
        // The cost at the optimum decomposes into its parts.
        assert!(
            (d.costs_j[d.l_opt] - d.client_energy_j - d.transmit_energy_j).abs() < 1e-12,
            "case {case}"
        );
        // Savings are well-defined percentages.
        assert!(d.savings_vs_fcc() <= 1.0 && d.savings_vs_fisc() <= 1.0);
    }
}

#[test]
fn prop_partition_decision_monotone_in_bitrate() {
    // As B_e grows, the optimal split should move (weakly) toward shallower
    // layers: transmission gets cheaper, so offloading earlier pays off.
    let mut rng = Rng::new(0xABBA);
    for case in 0..60 {
        let n_layers = rng.range_usize(2, 20);
        let mut cum = Vec::new();
        let mut acc = 0.0;
        for _ in 0..n_layers {
            acc += rng.next_f64() * 1e-3 + 1e-5;
            cum.push(acc);
        }
        // Volumes shrinking with depth (the CNN-typical case).
        let mut d_rlc = Vec::new();
        let mut v = 1e6;
        for _ in 0..n_layers {
            v *= 0.5 + rng.next_f64() * 0.45;
            d_rlc.push(v);
        }
        let policy = EnergyPolicy::new(Partitioner::from_parts(cum, d_rlc, 2_000_000, 8));
        let mut prev_opt = usize::MAX;
        for be in [1.0, 5.0, 25.0, 125.0, 625.0] {
            let env = TransmitEnv::with_effective_rate(be * 1e6, 0.78);
            let opt = fast_decide(&policy, 0.6, &env).l_opt;
            if prev_opt != usize::MAX {
                assert!(
                    opt <= prev_opt,
                    "case {case}: opt went deeper ({prev_opt} -> {opt}) as Be rose to {be}"
                );
            }
            prev_opt = opt;
        }
    }
}

/// Random synthetic partitioner: monotone cumulative energy, positive
/// transmit volumes (CNN-like or adversarially shuffled).
fn random_partitioner(rng: &mut Rng) -> Partitioner {
    let n_layers = rng.range_usize(1, 30);
    let mut cum = Vec::with_capacity(n_layers);
    let mut acc = 0.0;
    for _ in 0..n_layers {
        acc += rng.next_f64() * 1e-3 + 1e-9;
        cum.push(acc);
    }
    let d_rlc: Vec<f64> = (0..n_layers)
        .map(|_| rng.next_f64() * 1e6 + 1.0)
        .collect();
    Partitioner::from_parts(cum, d_rlc, 1_000_000, 8)
}

#[test]
fn prop_envelope_decide_matches_scan_argmin() {
    // The tentpole invariant: the envelope paths (EnergyPolicy::decide /
    // decide_batch) must reproduce the brute-force linear scan argmin
    // EXACTLY over a randomized (network, sparsity_in, B_e, P_Tx) grid —
    // same split, bit-identical cost.
    let mut rng = Rng::new(0x5EED);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let policy = EnergyPolicy::new(p.clone());
        let mut sps = Vec::new();
        for probe in 0..6 {
            // Log-uniform B_e over ~12 decades hits the extreme-γ corners
            // (everything-FISC and everything-FCC) as well as the
            // crossover region.
            let be = 10f64.powf(rng.next_f64() * 12.0 - 3.0);
            let p_tx = rng.next_f64() * 2.5 + 0.05;
            let env = TransmitEnv::with_effective_rate(be, p_tx);
            let sp = rng.next_f64();
            sps.push(sp);
            let scan = reference_scan(&policy, sp, &env); // reference linear scan
            let fast = fast_decide(&policy, sp, &env); // envelope path
            assert_eq!(
                fast.l_opt, scan.l_opt,
                "case {case}/{probe}: be={be} p_tx={p_tx} sp={sp}"
            );
            assert_eq!(
                fast.cost_j, scan.costs_j[scan.l_opt],
                "case {case}/{probe}: cost mismatch"
            );
            assert_eq!(fast.fcc_cost_j, scan.costs_j[0]);
            assert_eq!(
                fast.fisc_cost_j,
                scan.costs_j[scan.costs_j.len() - 1]
            );
        }
        // Batched decisions (one shared env) agree element-wise.
        let be = 10f64.powf(rng.next_f64() * 8.0 - 1.0);
        let env = TransmitEnv::with_effective_rate(be, rng.next_f64() * 2.0 + 0.1);
        let bits: Vec<f64> = sps
            .iter()
            .map(|&sp| p.input_bits_from_sparsity(sp))
            .collect();
        let mut batch = Vec::new();
        policy.decide_batch(&bits, &DecisionContext::from_input_bits(0.0, env), &mut batch);
        assert_eq!(batch.len(), sps.len(), "case {case}");
        for (&sp, choice) in sps.iter().zip(&batch) {
            let scan = reference_scan(&policy, sp, &env);
            assert_eq!(choice.l_opt, scan.l_opt, "case {case}: batch sp={sp}");
            assert_eq!(choice.cost_j, scan.costs_j[scan.l_opt]);
        }
    }
}

#[test]
fn prop_envelope_matches_scan_at_exact_breakpoints_and_ties() {
    // Tie cases: query γ EXACTLY at every envelope breakpoint (P_Tx = γ·B_e
    // with B_e = 1, so γ is reproduced bit-for-bit), where two candidate
    // lines cost the same and the scan's first-argmin rule must win; plus
    // duplicated candidate lines, which must resolve to the smallest split.
    let mut rng = Rng::new(0x71E5);
    for case in 0..120 {
        let p = random_partitioner(&mut rng);
        let policy = EnergyPolicy::new(p.clone());
        for (i, &gamma) in p.envelope().breakpoints().iter().enumerate() {
            for sp in [0.0, 0.5, 0.999] {
                let env = TransmitEnv::with_effective_rate(1.0, gamma);
                let scan = reference_scan(&policy, sp, &env);
                let fast = fast_decide(&policy, sp, &env);
                assert_eq!(
                    fast.l_opt, scan.l_opt,
                    "case {case}: breakpoint {i} γ={gamma} sp={sp}"
                );
                assert_eq!(fast.cost_j, scan.costs_j[scan.l_opt]);
            }
        }
    }
    // Duplicate lines: splits 1 and 2 identical, 3 cheap-to-send; the
    // envelope must tie-break toward split 1 exactly like the scan.
    let policy = EnergyPolicy::new(Partitioner::from_parts(
        vec![1e-3, 1e-3, 5e-3],
        vec![8e5, 8e5, 10.0],
        1_000_000,
        8,
    ));
    for be in [1e3, 1e6, 1e9, 1e12] {
        let env = TransmitEnv::with_effective_rate(be, 0.78);
        for sp in [0.1, 0.608, 0.95] {
            let scan = reference_scan(&policy, sp, &env);
            let fast = fast_decide(&policy, sp, &env);
            assert_eq!(fast.l_opt, scan.l_opt, "dup lines: be={be} sp={sp}");
        }
    }
}

#[test]
fn prop_degenerate_channel_is_guarded() {
    // B_e ≤ 0 used to divide by zero (NaN costs, argmin stuck at FCC);
    // the guard must route every path to FISC with finite, NaN-free
    // accounting.
    let mut rng = Rng::new(0xDEAD);
    for case in 0..60 {
        let p = random_partitioner(&mut rng);
        let policy = EnergyPolicy::new(p.clone());
        let n = p.num_layers();
        for be in [0.0, -1.0, f64::NAN] {
            let env = TransmitEnv::with_effective_rate(be, 0.78);
            let scan = reference_scan(&policy, rng.next_f64(), &env);
            assert_eq!(scan.l_opt, n, "case {case}: be={be}");
            assert!(scan.costs_j[n].is_finite());
            assert!(!scan.savings_vs_fcc().is_nan());
            assert!(!scan.savings_vs_fisc().is_nan());
            let fast = policy.decide(&DecisionContext::from_input_bits(
                rng.next_f64() * 1e6,
                env,
            ));
            assert_eq!(fast.l_opt, n);
            assert!(fast.cost_j.is_finite());
            assert!(!fast.savings_vs_fcc().is_nan());
            // The engine must also refuse to place a degenerate γ in any
            // envelope segment (overflow-lane routing at the front door).
            assert_eq!(p.envelope_segment(&env), None, "case {case}: be={be}");
            let bits = [
                p.input_bits_from_sparsity(0.2),
                p.input_bits_from_sparsity(0.8),
            ];
            let mut batch = Vec::new();
            policy.decide_batch(&bits, &DecisionContext::from_input_bits(0.0, env), &mut batch);
            assert!(batch.iter().all(|c| c.l_opt == n && c.cost_j.is_finite()));
        }
        // Non-finite γ from a corrupted power/rate report: no segment.
        let inf_rate = TransmitEnv::with_effective_rate(f64::INFINITY, 0.78);
        assert_eq!(p.envelope_segment(&inf_rate), None, "case {case}");
        let inf_power = TransmitEnv::with_effective_rate(80e6, f64::INFINITY);
        assert_eq!(p.envelope_segment(&inf_power), None, "case {case}");
    }
}

/// Random synthetic delay model sized to a partitioner: client latencies
/// dominate cloud ones (the paper's regime), all strictly positive.
fn random_delay_model(rng: &mut Rng, n_layers: usize) -> DelayModel {
    let client: Vec<f64> = (0..n_layers)
        .map(|_| rng.next_f64() * 1e-2 + 1e-6)
        .collect();
    let cloud: Vec<f64> = (0..n_layers)
        .map(|_| rng.next_f64() * 1e-4 + 1e-8)
        .collect();
    DelayModel::from_parts(client, cloud)
}

/// SLO fast path (the `SloPolicy` route) from a sparsity.
fn fast_slo_decide(
    slo_policy: &SloPolicy,
    sp: f64,
    env: &TransmitEnv,
    slo_s: f64,
) -> Decision {
    let ctx = DecisionContext::from_sparsity(slo_policy.partitioner(), sp, *env).with_slo(slo_s);
    slo_policy.decide(&ctx)
}

/// Compare the envelope-backed constrained decision against the reference
/// scan on one query — every shared field bit-for-bit.
fn assert_constrained_match(
    slo_policy: &SloPolicy,
    p: &Partitioner,
    dm: &DelayModel,
    sp: f64,
    env: &TransmitEnv,
    slo: f64,
    ctx: &str,
) {
    let scan = decide_with_slo_scan(p, dm, sp, env, slo);
    let fast = fast_slo_decide(slo_policy, sp, env, slo);
    assert_eq!(fast.l_opt, scan.l_opt, "l_opt: {ctx}");
    assert_eq!(fast.cost_j, scan.costs_j[scan.l_opt], "cost: {ctx}");
    assert_eq!(
        fast.t_delay_s.unwrap().to_bits(),
        scan.t_delay_s.unwrap().to_bits(),
        "t_delay ({:?} vs {:?}): {ctx}",
        fast.t_delay_s,
        scan.t_delay_s
    );
    assert_eq!(fast.feasible, scan.feasible, "feasible: {ctx}");
    assert_eq!(fast.binding, scan.binding, "binding: {ctx}");
    // The fast path's decomposition is exact by construction.
    assert_eq!(
        fast.client_energy_j + fast.transmit_energy_j,
        fast.cost_j,
        "decomposition: {ctx}"
    );
}

#[test]
fn prop_constrained_envelope_matches_scan() {
    // The PR-2 tentpole invariant, restated over the unified surface:
    // SloPolicy::decide (the envelope-backed path) must reproduce the
    // O(|L|) reference scan bit-for-bit across random SLOs (log-uniform,
    // zero, infinite, and exact delay ties), γ sweeps over ~12 decades,
    // and degenerate channels — splits, costs, delays, feasibility and
    // bindingness all identical.
    let mut rng = Rng::new(0x510C);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let dm = random_delay_model(&mut rng, p.num_layers());
        let slo_policy = SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone()));
        for probe in 0..8 {
            let be = 10f64.powf(rng.next_f64() * 12.0 - 3.0);
            let p_tx = rng.next_f64() * 2.5 + 0.05;
            let env = TransmitEnv::with_effective_rate(be, p_tx);
            let sp = rng.next_f64();
            let slo = match probe % 4 {
                0 => 10f64.powf(rng.next_f64() * 8.0 - 6.0),
                1 => 0.0,
                2 => f64::INFINITY,
                _ => {
                    // Exact tie: the SLO equals one candidate's delay, so
                    // that candidate is feasible by `<=` — the boundary the
                    // strict/loose inequality mix-ups would break.
                    let all = decide_with_slo_scan(&p, &dm, sp, &env, f64::INFINITY);
                    let k = rng.range_usize(0, all.delays_s.len() - 1);
                    all.delays_s[k]
                }
            };
            let ctx = format!("case {case}/{probe}: be={be} p_tx={p_tx} sp={sp} slo={slo}");
            assert_constrained_match(&slo_policy, &p, &dm, sp, &env, slo, &ctx);
        }
        // Degenerate channels: no panics, FISC, finite accounting.
        for be in [0.0, -1.0, f64::NAN] {
            let env = TransmitEnv::with_effective_rate(be, 0.78);
            let slo = rng.next_f64();
            let ctx = format!("case {case}: degenerate be={be} slo={slo}");
            assert_constrained_match(&slo_policy, &p, &dm, 0.5, &env, slo, &ctx);
            let fast = fast_slo_decide(&slo_policy, 0.5, &env, slo);
            assert_eq!(fast.l_opt, p.num_layers(), "{ctx}");
            assert!(fast.cost_j.is_finite(), "{ctx}");
            assert!(fast.t_delay_s.unwrap().is_finite(), "{ctx}");
        }
    }
}

#[test]
fn prop_constrained_matches_scan_at_energy_breakpoints() {
    // Query γ EXACTLY at every energy-envelope breakpoint (B_e = 1 so
    // P_Tx reproduces γ bit-for-bit) under a spread of SLOs: the cost tie
    // between two candidate lines and the SLO feasibility cut interact at
    // these points, and the scan's first-argmin rule must still win.
    let mut rng = Rng::new(0x7175);
    for case in 0..100 {
        let p = random_partitioner(&mut rng);
        let dm = random_delay_model(&mut rng, p.num_layers());
        let slo_policy = SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone()));
        let breakpoints: Vec<f64> = p.envelope().breakpoints().to_vec();
        for (i, gamma) in breakpoints.into_iter().enumerate() {
            let env = TransmitEnv::with_effective_rate(1.0, gamma);
            for slo in [0.0, 1e-2, 1e3, f64::INFINITY] {
                let ctx = format!("case {case}: breakpoint {i} γ={gamma} slo={slo}");
                assert_constrained_match(&slo_policy, &p, &dm, 0.6, &env, slo, &ctx);
            }
        }
    }
}

#[test]
fn prop_transmit_energy_decomposes_costs_exactly() {
    // `client_energy_j(l) + transmit_energy_j(l, ..)` must equal the scan's
    // costs_j[l] for EVERY split — exactly, not within tolerance: both
    // paths evaluate the identical floating-point expression.
    let mut rng = Rng::new(0xDEC0);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let policy = EnergyPolicy::new(p.clone());
        let env = TransmitEnv::with_effective_rate(
            10f64.powf(rng.next_f64() * 10.0 - 2.0),
            rng.next_f64() * 2.0 + 0.05,
        );
        let sp = rng.next_f64();
        let d = reference_scan(&policy, sp, &env);
        let input_bits = p.transmit_bits(0, sp);
        for split in 0..=p.num_layers() {
            let sum = p.client_energy_j(split) + p.transmit_energy_j(split, input_bits, &env);
            assert_eq!(sum, d.costs_j[split], "case {case} split {split}");
            assert_eq!(
                sum,
                p.candidate_cost_j(split, input_bits, &env),
                "case {case} split {split}"
            );
        }
    }
}

#[test]
fn prop_segment_decision_matches_per_request() {
    // γ-coherent admission invariant: once a request's γ is mapped to its
    // envelope segment at the front door, deciding inside that segment
    // must equal the per-request breakpoint-search path bit-for-bit, for
    // any jittered env whose γ stays in the segment (it does by
    // construction — both sides compute γ from the same env).
    let mut rng = Rng::new(0x6A33);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let policy = EnergyPolicy::new(p.clone());
        let base = 10f64.powf(rng.next_f64() * 8.0 - 1.0);
        let p_tx = rng.next_f64() * 2.0 + 0.1;
        for probe in 0..8 {
            // Clamped multiplicative jitter, like the coordinator's
            // admission-time sampling.
            let factor = (1.0 + 0.95 * (2.0 * rng.next_f64() - 1.0)).max(0.05);
            let env = TransmitEnv::with_effective_rate(base * factor, p_tx);
            let seg = p
                .envelope_segment(&env)
                .expect("positive-rate env has a segment");
            let ctx = DecisionContext::from_input_bits(
                p.transmit_bits(0, rng.next_f64()),
                env,
            );
            assert_eq!(
                policy.decide(&ctx.with_segment(seg)),
                policy.decide(&ctx),
                "case {case}/{probe}"
            );
        }
    }
}

#[test]
fn prop_policy_fast_paths_match_reference_scan_bit_for_bit() {
    // The api-redesign acceptance invariant, kept after the deprecated
    // wrappers were deleted: the PartitionPolicy fast paths (envelope,
    // batched, SLO) are provably equivalent to the reference scans — same
    // split, bit-identical costs, across random engines, ~12 decades of
    // B_e, ties and degenerate channels.
    let mut rng = Rng::new(0x90_11C7);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let energy = EnergyPolicy::new(p.clone());
        let dm = random_delay_model(&mut rng, p.num_layers());
        let slo_policy = SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone()));
        let mut sps = Vec::new();
        for probe in 0..6 {
            let be = 10f64.powf(rng.next_f64() * 12.0 - 3.0);
            let p_tx = rng.next_f64() * 2.5 + 0.05;
            let env = TransmitEnv::with_effective_rate(be, p_tx);
            let sp = rng.next_f64();
            sps.push(sp);
            let ctx = DecisionContext::from_sparsity(&p, sp, env);
            let d = energy.decide(&ctx);
            let full = energy.decide_detailed(&ctx);
            assert_eq!(d.l_opt, full.l_opt, "case {case}/{probe}");
            assert_eq!(d.cost_j, full.costs_j[full.l_opt], "case {case}/{probe}");
            assert_eq!(d.fcc_cost_j, full.costs_j[0]);
            assert_eq!(d.fisc_cost_j, full.costs_j[full.costs_j.len() - 1]);
            assert_eq!(d.client_energy_j, full.client_energy_j);
            assert_eq!(d.transmit_energy_j, full.transmit_energy_j);
            assert_eq!(d.transmit_bits, full.transmit_bits);
            // SLO fast path vs the reference SLO scan.
            let slo_s = 10f64.powf(rng.next_f64() * 8.0 - 6.0);
            let fast_slo = slo_policy.decide(&ctx.with_slo(slo_s));
            let scan_slo = decide_with_slo_scan(&p, &dm, sp, &env, slo_s);
            assert_eq!(fast_slo.l_opt, scan_slo.l_opt, "case {case}/{probe}");
            assert_eq!(fast_slo.cost_j, scan_slo.costs_j[scan_slo.l_opt]);
            assert_eq!(fast_slo.t_delay_s, scan_slo.t_delay_s);
            assert_eq!(fast_slo.feasible, scan_slo.feasible);
            assert_eq!(fast_slo.binding, scan_slo.binding);
            // SloPolicy::decide_detailed IS the reference scan.
            let detailed = slo_policy.decide_detailed(&ctx.with_slo(slo_s));
            assert_eq!(detailed, scan_slo, "case {case}/{probe}");
        }
        // Batched decisions vs per-request singles.
        let env = TransmitEnv::with_effective_rate(
            10f64.powf(rng.next_f64() * 8.0 - 1.0),
            rng.next_f64() * 2.0 + 0.1,
        );
        let bits: Vec<f64> = sps
            .iter()
            .map(|&sp| p.input_bits_from_sparsity(sp))
            .collect();
        let mut batch = Vec::new();
        energy.decide_batch(&bits, &DecisionContext::from_input_bits(0.0, env), &mut batch);
        assert_eq!(batch.len(), bits.len(), "case {case}");
        for (&b, d) in bits.iter().zip(&batch) {
            let single = energy.decide(&DecisionContext::from_input_bits(b, env));
            assert_eq!(d.l_opt, single.l_opt, "case {case}");
            assert_eq!(d.cost_j, single.cost_j, "case {case}");
        }
        // Degenerate channels through the trait path.
        for be in [0.0, -1.0, f64::NAN] {
            let env = TransmitEnv::with_effective_rate(be, 0.78);
            let ctx = DecisionContext::from_sparsity(&p, 0.5, env);
            let d = energy.decide(&ctx);
            assert_eq!(d.l_opt, p.num_layers(), "case {case}: be={be}");
            assert!(d.cost_j.is_finite());
            assert_eq!(d, energy.decide(&ctx.with_segment(3)), "case {case}: be={be}");
        }
    }
}

#[test]
fn prop_envelope_table_json_round_trip_is_bit_exact() {
    // EnvelopeTable invariant: decisions from a JSON-deserialized table
    // match the in-memory envelope EXACTLY — across random γ (12 decades),
    // exact breakpoint γ (cost ties between candidate lines), and
    // degenerate channels.
    let mut rng = Rng::new(0x7AB1E);
    for case in 0..150 {
        let p = random_partitioner(&mut rng);
        let table = EnvelopeTable::from_partitioner("synthetic", "test-device", 0.78, &p);
        let text = table.to_json();
        let back = EnvelopeTable::from_json(&text).expect("parse back");
        assert_eq!(back, table, "case {case}: struct round trip");
        let q = back.to_partitioner();
        // The rebuilt envelope is bit-identical.
        assert_eq!(q.envelope().breakpoints(), p.envelope().breakpoints(), "case {case}");
        assert_eq!(q.envelope().segments(), p.envelope().segments(), "case {case}");
        let a = EnergyPolicy::new(p.clone());
        let b = EnergyPolicy::new(q);
        let check = |env: TransmitEnv, sp: f64, ctx_label: &str| {
            let ctx = DecisionContext::from_sparsity(a.partitioner(), sp, env);
            let da = a.decide(&ctx);
            let db = b.decide(&ctx);
            assert_eq!(da, db, "case {case}: {ctx_label}");
            assert_eq!(da.cost_j.to_bits(), db.cost_j.to_bits(), "case {case}: {ctx_label}");
        };
        for probe in 0..8 {
            let be = 10f64.powf(rng.next_f64() * 12.0 - 3.0);
            let p_tx = rng.next_f64() * 2.5 + 0.05;
            check(TransmitEnv::with_effective_rate(be, p_tx), rng.next_f64(), "random γ");
        }
        // Exact breakpoints (B_e = 1 reproduces γ bit-for-bit as P_Tx).
        for &gamma in p.envelope().breakpoints() {
            check(TransmitEnv::with_effective_rate(1.0, gamma), 0.5, "breakpoint");
        }
        // Degenerate channels.
        for be in [0.0, -1.0, f64::NAN] {
            check(TransmitEnv::with_effective_rate(be, 0.78), 0.5, "degenerate");
        }
    }

    // The registry round-trips whole fleets the same way — and since the
    // fleet builder exports v2 artifacts, every imported entry keeps its
    // SLO engine.
    let registry = PolicyRegistry::new();
    registry.build_table_iv_fleet("alexnet").unwrap();
    let client = PolicyRegistry::new();
    let report = client.import_json(&registry.export_json()).unwrap();
    assert_eq!(report.imported, registry.len());
    assert_eq!(report.missing_slo, 0);
    assert_eq!(client.keys(), registry.keys());
    for (net, dev) in client.keys() {
        assert!(
            client.get(&net, &dev).unwrap().slo_policy().is_some(),
            "{net}/{dev} lost its SLO engine on import"
        );
    }
}

#[test]
fn prop_envelope_table_v2_slo_round_trip_is_bit_exact() {
    // The PR-5 tentpole invariant: an imported v2 EnvelopeTable (energy
    // tables + latency vectors) reconstructs an SLO engine whose decisions
    // — SloPolicy::decide over random SLOs/γ, including exact breakpoint
    // ties and degenerate channels — and admission-shedding lower bound
    // are bit-for-bit identical to the analytic engine it was exported
    // from.
    let mut rng = Rng::new(0x2B17_E5AC);
    for case in 0..150 {
        let p = random_partitioner(&mut rng);
        let dm = random_delay_model(&mut rng, p.num_layers());
        let table = EnvelopeTable::from_engines("synthetic", "test-device", 0.78, &p, &dm);
        assert!(table.has_slo_tables(), "case {case}");
        let text = table.to_json();
        let back = EnvelopeTable::from_json(&text).expect("parse back");
        assert_eq!(back, table, "case {case}: struct round trip");

        // Rebuild the full SLO stack from the deserialized artifact.
        let q = back.to_partitioner();
        let qdm = back.to_delay_model().expect("v2 carries latency tables");
        let analytic = SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone()));
        let imported = SloPolicy::new(SloPartitioner::new(q, qdm));

        let check = |env: TransmitEnv, sp: f64, slo: f64, label: &str| {
            let ctx_a = DecisionContext::from_sparsity(analytic.partitioner(), sp, env)
                .with_slo(slo);
            let da = analytic.decide(&ctx_a);
            let db = imported.decide(&ctx_a);
            assert_eq!(da, db, "case {case}: {label}");
            assert_eq!(da.cost_j.to_bits(), db.cost_j.to_bits(), "case {case}: {label}");
            assert_eq!(
                da.t_delay_s.unwrap().to_bits(),
                db.t_delay_s.unwrap().to_bits(),
                "case {case}: {label}"
            );
            // The admission-shedding bound is part of the SLO surface too.
            assert_eq!(
                analytic
                    .slo_partitioner()
                    .min_delay_lower_bound_s(&env)
                    .to_bits(),
                imported
                    .slo_partitioner()
                    .min_delay_lower_bound_s(&env)
                    .to_bits(),
                "case {case}: lower bound at {label}"
            );
        };
        for probe in 0..8 {
            let be = 10f64.powf(rng.next_f64() * 12.0 - 3.0);
            let p_tx = rng.next_f64() * 2.5 + 0.05;
            let slo = match probe % 3 {
                0 => 10f64.powf(rng.next_f64() * 8.0 - 6.0),
                1 => 0.0,
                _ => f64::INFINITY,
            };
            check(
                TransmitEnv::with_effective_rate(be, p_tx),
                rng.next_f64(),
                slo,
                "random γ/SLO",
            );
        }
        // Exact energy breakpoints and delay-envelope breakpoints.
        for &gamma in p.envelope().breakpoints() {
            check(TransmitEnv::with_effective_rate(1.0, gamma), 0.5, 1e-3, "energy breakpoint");
        }
        // Degenerate channels.
        for be in [0.0, -1.0, f64::NAN] {
            check(TransmitEnv::with_effective_rate(be, 0.78), 0.5, 0.25, "degenerate");
        }
    }
}

#[test]
fn prop_envelope_table_v3_blob_round_trip_is_bit_exact() {
    // The v3 tentpole invariant: the flat binary fleet blob reproduces
    // the EnvelopeTable struct exactly (and agrees with the v2 JSON form
    // both ways), and decisions off a blob-decoded engine — EnergyPolicy
    // and SloPolicy alike — are bit-for-bit identical to the analytic
    // engine across random γ, exact breakpoint ties and degenerate
    // channels.
    let mut rng = Rng::new(0xB10B);
    for case in 0..120 {
        let p = random_partitioner(&mut rng);
        let dm = random_delay_model(&mut rng, p.num_layers());
        let with_slo = case % 2 == 0;
        let table = if with_slo {
            EnvelopeTable::from_engines("synthetic", "test-device", 0.78, &p, &dm)
        } else {
            EnvelopeTable::from_partitioner("synthetic", "test-device", 0.78, &p)
        };
        // struct → v3 → struct is lossless...
        let blob = FleetBlob::open(FleetBlob::encode([&table])).expect("open own encoding");
        assert_eq!(blob.len(), 1, "case {case}");
        let back = blob.entry(0).expect("decode entry");
        assert_eq!(back, table, "case {case}: v3 struct round trip");
        // ...and lands on the identical v2 JSON document.
        let via_json = EnvelopeTable::from_json(&table.to_json()).expect("parse back");
        assert_eq!(back.to_json(), via_json.to_json(), "case {case}: v3 vs v2 JSON");

        let q = back.to_partitioner();
        let a = EnergyPolicy::new(p.clone());
        let b = EnergyPolicy::new(q.clone());
        let slo_pair = if with_slo {
            let qdm = back.to_delay_model().expect("v3 carries latency tables");
            Some((
                SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone())),
                SloPolicy::new(SloPartitioner::new(q.clone(), qdm)),
            ))
        } else {
            None
        };
        let check = |env: TransmitEnv, sp: f64, label: &str| {
            let ctx = DecisionContext::from_sparsity(a.partitioner(), sp, env);
            let da = a.decide(&ctx);
            let db = b.decide(&ctx);
            assert_eq!(da, db, "case {case}: {label}");
            assert_eq!(
                da.cost_j.to_bits(),
                db.cost_j.to_bits(),
                "case {case}: {label}"
            );
            if let Some((sa, sb)) = &slo_pair {
                let slo_ctx = ctx.with_slo(1e-3);
                assert_eq!(sa.decide(&slo_ctx), sb.decide(&slo_ctx), "case {case}: slo {label}");
            }
        };
        for _ in 0..6 {
            let be = 10f64.powf(rng.next_f64() * 12.0 - 3.0);
            let p_tx = rng.next_f64() * 2.5 + 0.05;
            check(
                TransmitEnv::with_effective_rate(be, p_tx),
                rng.next_f64(),
                "random γ",
            );
        }
        // Exact breakpoints (B_e = 1 reproduces γ bit-for-bit as P_Tx).
        for &gamma in p.envelope().breakpoints() {
            check(TransmitEnv::with_effective_rate(1.0, gamma), 0.5, "breakpoint");
        }
        // Degenerate channels.
        for be in [0.0, -1.0, f64::NAN] {
            check(TransmitEnv::with_effective_rate(be, 0.78), 0.5, "degenerate");
        }
    }

    // Registry level: v2 JSON ↔ v3 blob is lossless in both directions
    // and byte-stable (sorted-map iteration fixes entry order).
    let registry = PolicyRegistry::new();
    registry.build_table_iv_fleet("alexnet").unwrap();
    let v2 = registry.export_json();
    let blob = registry.export_v3();
    let from_blob = PolicyRegistry::new();
    let report = from_blob.import_v3(&blob).unwrap();
    assert_eq!(report.imported, registry.len());
    assert_eq!(report.missing_slo, 0);
    assert_eq!(from_blob.export_json(), v2, "v3 → v2 JSON not lossless");
    let from_json = PolicyRegistry::new();
    from_json.import_json(&v2).unwrap();
    assert_eq!(from_json.export_v3(), blob, "v2 JSON → v3 not byte-stable");
}

#[test]
fn prop_lane_batch_kernel_matches_single_decides() {
    // The struct-of-arrays batch kernel (decide_lane_batch over
    // per-request channel states) must reproduce per-request decide
    // bit-for-bit — random γ, exact breakpoint ties, degenerate and
    // free-radio channels in the same drained batch.
    let mut rng = Rng::new(0x1A9E5);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let policy = EnergyPolicy::new(p.clone());
        let mut lanes = BatchLanes::new();
        let mut envs = Vec::new();
        for probe in 0..12 {
            let env = match probe {
                0 => TransmitEnv::with_effective_rate(0.0, 0.78),
                1 => TransmitEnv::with_effective_rate(f64::NAN, 0.78),
                2 => TransmitEnv::with_effective_rate(1.0, 0.0),
                _ => TransmitEnv::with_effective_rate(
                    10f64.powf(rng.next_f64() * 12.0 - 3.0),
                    rng.next_f64() * 2.5 + 0.05,
                ),
            };
            envs.push(env);
            lanes.push(p.input_bits_from_sparsity(rng.next_f64()), env);
        }
        for &gamma in p.envelope().breakpoints() {
            let env = TransmitEnv::with_effective_rate(1.0, gamma);
            envs.push(env);
            lanes.push(p.input_bits_from_sparsity(0.5), env);
        }
        let mut out = Vec::new();
        let ctx = DecisionContext::from_input_bits(0.0, envs[0]);
        policy.decide_lane_batch(&mut lanes, &ctx, &mut out);
        assert_eq!(out.len(), lanes.len(), "case {case}");
        for i in 0..out.len() {
            let single =
                policy.decide(&DecisionContext::from_input_bits(lanes.input_bits()[i], envs[i]));
            assert_eq!(out[i], single, "case {case} lane {i}");
            assert_eq!(
                out[i].cost_j.to_bits(),
                single.cost_j.to_bits(),
                "case {case} lane {i}"
            );
        }
    }
}

#[test]
fn prop_sparsity_envelope_policy_matches_sparsity_linear_scan() {
    // SparsityEnvelopePolicy invariant: at a fixed channel state, the
    // two-lookup probe-side decision equals the full linear scan for every
    // Sparsity-In — bit-for-bit, including the crossover neighborhood and
    // the endpoints.
    let mut rng = Rng::new(0x5EA5);
    for case in 0..CASES {
        let p = random_partitioner(&mut rng);
        let energy = EnergyPolicy::new(p.clone());
        let be = 10f64.powf(rng.next_f64() * 10.0 - 2.0);
        let p_tx = rng.next_f64() * 2.5 + 0.05;
        let env = TransmitEnv::with_effective_rate(be, p_tx);
        let policy = SparsityEnvelopePolicy::new(p.clone(), env);
        let mut sparsities = vec![0.0, 1.0, rng.next_f64(), rng.next_f64(), rng.next_f64()];
        if let Some(s_star) = policy.crossover_sparsity() {
            // Probe the closed-form threshold's neighborhood.
            for delta in [-1e-3, 0.0, 1e-3] {
                let s = (s_star + delta).clamp(0.0, 1.0);
                sparsities.push(s);
            }
        }
        for (probe, &sp) in sparsities.iter().enumerate() {
            let d = policy.decide_sparsity(sp);
            let scan = reference_scan(&energy, sp, &env);
            assert_eq!(d.l_opt, scan.l_opt, "case {case}/{probe}: be={be} p_tx={p_tx} sp={sp}");
            assert_eq!(d.cost_j, scan.costs_j[scan.l_opt], "case {case}/{probe}");
            assert_eq!(d.fcc_cost_j, scan.costs_j[0], "case {case}/{probe}");
            // The trait route (sparsity carried on the context) agrees.
            let via_ctx = policy.decide(&DecisionContext::from_sparsity(&p, sp, env));
            assert_eq!(via_ctx, d, "case {case}/{probe}");
        }
        // Degenerate channel: guarded FISC fallback, like every path.
        let dead = TransmitEnv::with_effective_rate(0.0, p_tx);
        let dead_policy = SparsityEnvelopePolicy::new(p.clone(), dead);
        let d = dead_policy.decide_sparsity(0.5);
        assert_eq!(d.l_opt, p.num_layers(), "case {case}");
        assert!(d.cost_j.is_finite(), "case {case}");
    }
}

#[test]
fn prop_json_round_trip() {
    let mut rng = Rng::new(0xD1CE);
    for _ in 0..CASES {
        let v = random_json(&mut rng, 0);
        let text = json::to_string(&v);
        let back = json::parse(&text).expect("round trip parse");
        assert_eq!(back, v, "text: {text}");
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> json::Value {
    use json::Value;
    let choice = if depth > 3 {
        rng.range_usize(0, 3)
    } else {
        rng.range_usize(0, 5)
    };
    match choice {
        0 => Value::Null,
        1 => Value::Bool(rng.next_f64() < 0.5),
        2 => Value::Num((rng.next_f64() * 2e6).round() - 1e6),
        3 => {
            let n = rng.range_usize(0, 12);
            Value::Str((0..n).map(|_| *rng.choose(&['a', 'b', '"', '\\', 'ß', '\n'])).collect())
        }
        4 => {
            let n = rng.range_usize(0, 5);
            Value::Arr((0..n).map(|_| random_json(rng, depth + 1)).collect())
        }
        _ => {
            let n = rng.range_usize(0, 5);
            Value::Obj(
                (0..n)
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            )
        }
    }
}

// ---- compiled NetworkProfile (PR 4) ----

/// Random-but-valid energy model: one of the paper's two operating points,
/// optionally rescaled to a random GLB size and client throughput — the
/// knobs engine builds and sweeps actually turn.
fn random_model(rng: &mut Rng) -> CnnErgy {
    let mut model = if rng.next_f64() < 0.5 {
        CnnErgy::inference_8bit()
    } else {
        CnnErgy::eyeriss_16bit()
    };
    if rng.next_f64() < 0.7 {
        model = model.with_glb_size(rng.range_usize(4, 512) * 1024 + rng.range_usize(0, 1023));
    }
    if rng.next_f64() < 0.5 {
        model.hw.throughput_macs *= 0.25 + rng.next_f64();
    }
    model
}

#[test]
fn prop_profile_backed_engines_bit_identical_to_fresh_builds() {
    // The tentpole contract: a profile-backed engine build (table slicing)
    // reproduces the direct full-model build bit for bit — tables,
    // envelopes, delay sums and decisions — across random hardware/tech
    // points, GLB sizes, sparsities and degenerate channels.
    let mut rng = Rng::new(0x9420_F11E);
    let nets = [
        Network::by_name("alexnet").unwrap(),
        Network::by_name("squeezenet").unwrap(),
        Network::by_name("googlenet").unwrap(),
        Network::by_name("tiny_alexnet").unwrap(),
    ];
    for case in 0..40 {
        let net = rng.choose(&nets);
        let model = random_model(&mut rng);
        let profile = NetworkProfile::compute(net, &model);
        let ctx_s = format!("case {case}: {} glb={}", net.name, model.hw.glb_bytes);

        // Profile tables == direct model queries.
        assert_eq!(profile.breakdowns(), model.network_breakdowns(net).as_slice(), "{ctx_s}");
        assert_eq!(
            profile.cumulative_energy_pj(),
            model.cumulative_energy_pj(net).as_slice(),
            "{ctx_s}"
        );
        assert_eq!(profile.latencies_s(), model.layer_latencies_s(net).as_slice(), "{ctx_s}");
        assert_eq!(profile.total_energy_pj(), model.total_energy_pj(net), "{ctx_s}");

        // Engine tables == fresh builds.
        let fresh_p = Partitioner::new(net, &model);
        let prof_p = Partitioner::from_profile(&profile);
        assert_eq!(prof_p.energy_table_j(), fresh_p.energy_table_j(), "{ctx_s}");
        assert_eq!(prof_p.volume_table_bits(), fresh_p.volume_table_bits(), "{ctx_s}");
        assert_eq!(prof_p.input_raw_bits(), fresh_p.input_raw_bits(), "{ctx_s}");
        assert_eq!(prof_p.envelope().breakpoints(), fresh_p.envelope().breakpoints(), "{ctx_s}");
        assert_eq!(prof_p.envelope().segments(), fresh_p.envelope().segments(), "{ctx_s}");
        let fresh_dm = DelayModel::new(net, &model);
        let prof_dm = DelayModel::from_profile(&profile);
        for split in 0..=net.num_layers() {
            assert_eq!(
                prof_dm.client_prefix_s(split),
                fresh_dm.client_prefix_s(split),
                "{ctx_s} split {split}"
            );
            assert_eq!(
                prof_dm.cloud_suffix_s(split),
                fresh_dm.cloud_suffix_s(split),
                "{ctx_s} split {split}"
            );
        }

        // Decisions — energy and SLO policies — across random channel
        // states including degenerate ones, and random probe sparsities.
        let fresh_energy = EnergyPolicy::new(fresh_p.clone());
        let prof_energy = EnergyPolicy::new(prof_p.clone());
        let fresh_slo = SloPolicy::new(SloPartitioner::new(fresh_p, fresh_dm));
        let prof_slo = SloPolicy::new(SloPartitioner::new(prof_p, prof_dm));
        for _ in 0..8 {
            let b_e = *rng.choose(&[0.0, -3.0, f64::NAN, 1e4, 1e6, 8e7, 2e8, 1e12]);
            let p_tx = *rng.choose(&[0.0, 0.25, 0.78, 1.28, 2.5]);
            let env = TransmitEnv::with_effective_rate(b_e, p_tx);
            let sp = rng.next_f64();
            let ctx = DecisionContext::from_sparsity(prof_energy.partitioner(), sp, env);
            assert_eq!(prof_energy.decide(&ctx), fresh_energy.decide(&ctx), "{ctx_s}");
            let slo_s = rng.next_f64() * 0.05;
            let slo_ctx = ctx.with_slo(slo_s);
            assert_eq!(prof_slo.decide(&slo_ctx), fresh_slo.decide(&slo_ctx), "{ctx_s}");
        }
    }
}

#[test]
fn prop_incremental_glb_resweep_bit_identical_to_rebuild() {
    // The incremental sweep contract: resizing a compiled profile's GLB
    // re-derives only the schedule/GLB-dependent terms, yet every table
    // matches a cold full rebuild at the resized model bit for bit.
    let mut rng = Rng::new(0x61B5_3EE9);
    let nets = [
        Network::by_name("alexnet").unwrap(),
        Network::by_name("squeezenet").unwrap(),
    ];
    for case in 0..30 {
        let net = rng.choose(&nets);
        let model = if rng.next_f64() < 0.5 {
            CnnErgy::inference_8bit()
        } else {
            CnnErgy::eyeriss_16bit()
        };
        let base = model.compiled(net);
        let glb = rng.range_usize(2, 600) * 1024 + rng.range_usize(0, 1023);
        let resized = base.with_glb_size(glb);
        let fresh_model = model.with_glb_size(glb);
        let ctx_s = format!("case {case}: {} glb={glb}", net.name);

        assert_eq!(resized.total_energy_pj(), fresh_model.total_energy_pj(net), "{ctx_s}");
        assert_eq!(
            resized.breakdowns(),
            fresh_model.network_breakdowns(net).as_slice(),
            "{ctx_s}"
        );
        assert_eq!(
            resized.latencies_s(),
            fresh_model.layer_latencies_s(net).as_slice(),
            "{ctx_s}"
        );
        // The volume side is GLB-independent and reused verbatim.
        assert_eq!(resized.d_rlc_bits(), base.d_rlc_bits(), "{ctx_s}");
        assert_eq!(resized.input_raw_bits(), base.input_raw_bits(), "{ctx_s}");
        // An engine sliced from the resized profile == a fresh build.
        let p_inc = Partitioner::from_profile(&resized);
        let p_fresh = Partitioner::new(net, &fresh_model);
        assert_eq!(p_inc.energy_table_j(), p_fresh.energy_table_j(), "{ctx_s}");
        assert_eq!(p_inc.envelope().breakpoints(), p_fresh.envelope().breakpoints(), "{ctx_s}");
    }
}
