//! Health-plane e2e: the recoverable serving path.
//!
//! Runs on the artifact-free deterministic sim backend
//! (`ExecutorBackend::Sim`), so like `chaos_e2e` this suite never skips.
//! Where the chaos suite asserts the *bounded-outcome* contract under
//! injected faults, this suite asserts the *recovery* contract on top of
//! it: a shard whose cloud pool is replaced mid-run or whose Markov
//! outage ends returns to partitioned serving without a restart (circuit
//! breaker), a traffic burst sheds its loose-deadline overload instead
//! of queueing it while clean load sheds nothing (brownout), and an
//! injected model skew is detected, calibrated and quarantined while
//! unaffected device classes stay bit-identical (drift watchdog).

use std::path::PathBuf;
use std::time::Duration;

use neupart::channel::{FaultConfig, MarkovOutage, TransmitEnv};
use neupart::coordinator::{
    loadgen, ArrivalModel, BrownoutConfig, Coordinator, CoordinatorConfig, DriftState,
    ExecutorBackend, HealthConfig, InferenceOutcome, InferenceRequest, LoadGenConfig, RetryPolicy,
    ServingTier, ServingTierConfig,
};
use neupart::corpus::Corpus;

fn config() -> CoordinatorConfig {
    CoordinatorConfig {
        // Never read by the sim backend.
        artifacts_dir: PathBuf::from("artifacts"),
        network: "tiny_alexnet".to_string(),
        env: TransmitEnv::with_effective_rate(130.0e6, 0.78),
        jpeg_quality: 90,
        cloud_pool: 2,
        workers: 2,
        jitter: 0.0,
        time_scale: 0.0,
        force_split: None,
        warm_splits: Vec::new(),
        batch_max: 3,
        gamma_coherent: true,
        shed_infeasible: true,
        backend: ExecutorBackend::Sim,
        faults: None,
        scenario: None,
        redecide: None,
        retry: RetryPolicy::default(),
        health: HealthConfig::default(),
        seed: 42,
    }
}

fn requests(n: usize) -> Vec<InferenceRequest> {
    Corpus::new(32, 32, 17)
        .iter(n)
        .enumerate()
        .map(|(i, img)| {
            InferenceRequest::new(i as u64, img.to_f32_nhwc(), img.pixels, img.w, img.h)
        })
        .collect()
}

/// Serve small batches until `done` reports true, sleeping between
/// rounds so wall-clock machinery (the breaker cooldown) can elapse.
fn serve_until(coord: &Coordinator, rounds: usize, done: impl Fn(&Coordinator) -> bool) -> bool {
    for _ in 0..rounds {
        coord.serve(requests(2)).expect("serve");
        if done(coord) {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    false
}

#[test]
fn replaced_cloud_pool_reopens_breaker_and_restores_partitioned_serving() {
    let mut cfg = config();
    cfg.force_split = Some(3); // partitioned: every request needs the cloud
    let coord = Coordinator::new(cfg).unwrap();

    // Healthy baseline: partitioned serving through the original pool.
    let healthy = coord.serve(requests(4)).unwrap();
    assert!(healthy.iter().all(InferenceOutcome::is_ok));

    coord.kill_cloud_pool();
    let cloud = coord.cloud_handle();
    for _ in 0..500 {
        if cloud.alive_threads() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(cloud.alive_threads(), 0, "killed pool still alive");
    drop(cloud);

    // The dead pool force-opens the breaker; requests complete
    // client-only instead of failing.
    let tripped = coord.serve(requests(4)).unwrap();
    assert!(tripped.iter().all(InferenceOutcome::is_degraded));
    assert!(coord.is_degraded());
    assert!(coord.metrics.snapshot().degraded_mode_entered >= 1);

    // Chaos hook: swap in a fresh pool mid-run — no restart, no rebuild.
    coord.replace_cloud_pool().unwrap();

    // The cooldown elapses, a half-open probe lands on the new pool and
    // the breaker closes again.
    let reopened = serve_until(&coord, 1000, |c| c.metrics.snapshot().breaker_reopened >= 1);
    assert!(reopened, "breaker never reopened after the pool was replaced");
    assert!(!coord.is_degraded());

    // Partitioned serving is back: remote path, forced split honored.
    let recovered = coord.serve(requests(6)).unwrap();
    for o in &recovered {
        assert!(o.is_ok(), "post-recovery request not Ok: {o:?}");
        let r = o.response().unwrap();
        assert_eq!(r.split, 3, "post-recovery serving must be partitioned");
        assert!(r.transmit_bits > 0, "recovered path must use the radio");
    }
    let m = coord.metrics.snapshot();
    assert!(m.breaker_probes >= 1, "recovery must go through a probe");
    assert_eq!(m.failed_requests, 0);
}

#[test]
fn markov_outage_end_is_discovered_by_probes_and_reopens() {
    let mut cfg = config();
    cfg.force_split = Some(3); // partitioned: every request needs the uplink
    cfg.faults = Some(FaultConfig {
        drop_prob: 0.0,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        // Mostly-down link: every up step decays immediately, downs
        // recover per send with p = 0.4 — a ~70% remote failure rate
        // trips the breaker, while the seeded draw sequence guarantees
        // probes eventually land inside an up window.
        outage: Some(MarkovOutage {
            p_up_to_down: 1.0,
            p_down_to_up: 0.4,
        }),
        seed: 29,
    });
    cfg.retry = RetryPolicy {
        max_attempts: 1,
        ..RetryPolicy::default()
    };
    let coord = Coordinator::new(cfg).unwrap();

    // Serve until the windowed error rate trips the breaker. Nothing
    // may fail outright: rejected sends degrade through the FISC
    // fallback.
    let mut tripped = false;
    for _ in 0..50 {
        let out = coord.serve(requests(8)).unwrap();
        assert!(out.iter().all(|o| !o.is_failed()));
        if coord.metrics.snapshot().degraded_mode_entered >= 1 {
            tripped = true;
            break;
        }
    }
    assert!(tripped, "a ~70%-failing link never tripped the breaker");

    // Deny routes never touch the radio, so only half-open probes can
    // advance the Markov chain and observe the outage ending.
    let reopened = serve_until(&coord, 1000, |c| c.metrics.snapshot().breaker_reopened >= 1);
    assert!(reopened, "probes never observed the outage ending");
    let m = coord.metrics.snapshot();
    assert!(m.breaker_probes >= 1);
    assert!(m.outage_rejections >= 1);
    assert_eq!(m.failed_requests, 0);
}

#[test]
fn brownout_sheds_burst_overload_but_never_clean_load() {
    let mut shard = config();
    // Watermarks pulled low so the verdict does not ride on
    // producer/worker timing margins: the per-shard queue capacity is
    // 16, so clean closed-loop load (≤ 2 queued) sits far below the
    // soft watermark while an open flood saturates it.
    shard.health.brownout = BrownoutConfig {
        enabled: true,
        soft_watermark: 0.25,
        hard_watermark: 0.5,
        loose_headroom_s: 1.0,
    };
    let mut lg = LoadGenConfig::table_iv_wlan(2_000, 21);
    lg.infeasible_frac = 0.0;
    let tier = |lg: &LoadGenConfig| {
        ServingTier::new(ServingTierConfig::per_class(shard.clone(), &lg.class_envs())).unwrap()
    };

    // Clean closed-loop load: the queue never nears the watermarks.
    lg.arrival = ArrivalModel::Closed { concurrency: 2 };
    let clean = loadgen::run(&tier(&lg), &lg).unwrap();
    assert_eq!(clean.shed, 0, "clean load must not shed at all");
    assert_eq!(clean.completed, clean.clients);

    // Open burst over the same fleet: the flood sheds via the brownout
    // reason instead of queueing unboundedly.
    lg.arrival = ArrivalModel::Burst {
        concurrency: 2,
        producers: 8,
        clean_fraction: 0.25,
    };
    let burst = loadgen::run(&tier(&lg), &lg).unwrap();
    assert_eq!(burst.completed + burst.shed, burst.clients);
    assert!(burst.shed_brownout > 0, "open flood never hit the hard watermark");
    assert_eq!(burst.shed_infeasible, 0);
    assert_eq!(
        burst.shed,
        burst.shed_infeasible + burst.shed_overflow + burst.shed_brownout,
        "every shed must carry a reason"
    );
}

#[test]
fn mild_model_skew_detects_and_calibrates_without_quarantine() {
    let mut cfg = config();
    cfg.force_split = Some(4); // a real client prefix feeds the watchdog
    let coord = Coordinator::new(cfg).unwrap();
    coord.set_model_skew(1.4, 1.4);

    let n = 32;
    let out = coord.serve(requests(n)).unwrap();
    assert!(out.iter().all(InferenceOutcome::is_ok));
    let m = coord.metrics.snapshot();
    assert_eq!(
        m.drift_detect_requests, n as u64,
        "every 1.4x residual is outside the 25% band"
    );
    assert!(m.drift_calibrations >= 1, "EWMA must cross the band edge");
    assert_eq!(m.drift_quarantines, 0, "1.4x is below the 1.75x quarantine ratio");
    assert_eq!(coord.drift_state(), DriftState::Calibrated);
    assert!(
        m.calibration_factor > 1.25 && m.calibration_factor < 1.45,
        "calibration factor {} must track the injected 1.4x skew",
        m.calibration_factor
    );
}

#[test]
fn heavy_model_skew_quarantines_to_conservative_policy() {
    let mut cfg = config();
    // Well below the FCC/FISC crossover (~130 Mbps): the policy decides
    // FISC, so every request runs a client prefix and feeds the
    // watchdog, and the quarantine override (policy decisions only) is
    // reachable.
    cfg.env = TransmitEnv::with_effective_rate(40.0e6, 0.78);
    let coord = Coordinator::new(cfg).unwrap();
    let n_layers = coord.partitioner().num_layers();
    coord.set_model_skew(2.0, 2.0);

    let out = coord.serve(requests(24)).unwrap();
    assert!(out.iter().all(|o| !o.is_failed()));
    let m = coord.metrics.snapshot();
    assert!(m.drift_detect_requests >= 8);
    assert!(m.drift_quarantines >= 1, "2x skew must quarantine");
    assert_eq!(coord.drift_state(), DriftState::Quarantined);
    assert!(m.drift_quarantined_requests >= 1, "quarantine must reroute requests");

    // Quarantined routing is the conservative policy: one of the two
    // envelope endpoints, uniformly, until residuals recover.
    let follow = coord.serve(requests(8)).unwrap();
    let splits: Vec<usize> = follow
        .iter()
        .map(|o| o.response().expect("quarantined request must serve").decided_split)
        .collect();
    assert!(
        splits.iter().all(|s| *s == splits[0]),
        "conservative routing must be uniform, got {splits:?}"
    );
    assert!(
        splits[0] == 0 || splits[0] == n_layers,
        "conservative split must be an envelope endpoint, got {}",
        splits[0]
    );
}

#[test]
fn model_skew_quarantine_is_isolated_to_its_own_shard() {
    // Even ids report the victim's class (0.78 W), odd ids the
    // sibling's — both well on the FISC side so every request feeds its
    // shard's watchdog.
    let mk_reqs = || {
        let mut reqs = requests(16);
        for (i, r) in reqs.iter_mut().enumerate() {
            let p_tx = if i % 2 == 0 { 0.78 } else { 1.28 };
            r.env = Some(TransmitEnv::with_effective_rate(40.0e6, p_tx));
        }
        reqs
    };
    let envs = [
        TransmitEnv::with_effective_rate(40.0e6, 0.78),
        TransmitEnv::with_effective_rate(40.0e6, 1.28),
    ];
    let build = || ServingTier::new(ServingTierConfig::per_class(config(), &envs)).unwrap();

    let skewed = build();
    skewed.shards()[0].set_model_skew(2.0, 2.0);
    let reference = build();

    let out_a = skewed.serve(mk_reqs()).unwrap();
    let out_b = reference.serve(mk_reqs()).unwrap();

    // The victim class detected drift; the sibling class stayed nominal.
    assert!(skewed.shards()[0].metrics.snapshot().drift_detect_requests >= 1);
    assert_eq!(
        skewed.shards()[1].metrics.snapshot().drift_detect_requests,
        0,
        "drift detection leaked across shards"
    );
    assert_eq!(skewed.shards()[1].drift_state(), DriftState::Nominal);

    // The unaffected class is bit-identical to the no-skew reference.
    for (i, (x, y)) in out_a.iter().zip(&out_b).enumerate() {
        if i % 2 == 0 {
            continue;
        }
        let rx = x.response().expect("sibling request must serve");
        let ry = y.response().expect("reference request must serve");
        assert_eq!(rx.split, ry.split, "sibling split perturbed by foreign skew");
        assert_eq!(rx.decided_split, ry.decided_split);
        assert_eq!(rx.logits, ry.logits, "sibling logits perturbed by foreign skew");
        assert_eq!(rx.client_energy_j.to_bits(), ry.client_energy_j.to_bits());
        assert_eq!(rx.transmit_energy_j.to_bits(), ry.transmit_energy_j.to_bits());
    }
}
