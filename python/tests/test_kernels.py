"""L1 correctness: Pallas kernels vs pure-jnp oracles.

This is the core correctness signal for the compiled artifacts: everything
the Rust runtime executes is built from these kernels. Hypothesis sweeps the
shape/stride/dtype space; fixed cases pin the exact configurations the Tiny*
networks use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, linear
from compile.kernels.ref import conv2d_ref, linear_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

conv_cases = st.tuples(
    st.integers(1, 2),  # N
    st.integers(1, 3),  # extra spatial room
    st.integers(1, 3),
    st.sampled_from([1, 3, 4, 8]),  # C
    st.sampled_from([1, 2, 4, 16]),  # F
    st.sampled_from([1, 3, 5]),  # R=S
    st.sampled_from([1, 2]),  # stride
)


@settings(max_examples=25, deadline=None)
@given(conv_cases, st.booleans())
def test_conv2d_matches_ref(case, apply_relu):
    n, eh, ew, c, f, r, u = case
    # Build a stride-aligned padded input: Hp = (E-1)*U + R.
    e, g = eh + 1, ew + 1
    hp, wp = (e - 1) * u + r, (g - 1) * u + r
    rng = np.random.default_rng(hash(case) % 2**32)
    x = _rand(rng, (n, hp, wp, c), jnp.float32)
    w = _rand(rng, (r, r, c, f), jnp.float32)
    b = _rand(rng, (f,), jnp.float32)

    got = conv2d(x, w, b, stride=u, apply_relu=apply_relu)
    want = conv2d_ref(x, w, b, stride=u, apply_relu=apply_relu)
    assert got.shape == (n, e, g, f)
    np.testing.assert_allclose(got, want, **_tol(jnp.float32))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_conv2d_dtypes(dtype):
    rng = np.random.default_rng(7)
    x = _rand(rng, (1, 10, 10, 8), dtype)
    w = _rand(rng, (3, 3, 8, 16), dtype)
    b = _rand(rng, (16,), dtype)
    got = conv2d(x, w, b, stride=1)
    want = conv2d_ref(x, w, b, stride=1)
    assert got.dtype == dtype
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


@pytest.mark.parametrize(
    "c,f,cb,fb",
    [(8, 16, 2, 4), (8, 16, 8, 16), (6, 9, 3, 3), (4, 4, 1, 1)],
)
def test_conv2d_block_overrides(c, f, cb, fb):
    """Accumulation across channel blocks must be exact regardless of tiling."""
    rng = np.random.default_rng(11)
    x = _rand(rng, (1, 7, 7, c), jnp.float32)
    w = _rand(rng, (3, 3, c, f), jnp.float32)
    b = _rand(rng, (f,), jnp.float32)
    got = conv2d(x, w, b, c_block=cb, f_block=fb)
    want = conv2d_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_conv2d_rejects_bad_blocks():
    rng = np.random.default_rng(3)
    x = _rand(rng, (1, 5, 5, 6), jnp.float32)
    w = _rand(rng, (3, 3, 6, 4), jnp.float32)
    b = _rand(rng, (4,), jnp.float32)
    with pytest.raises(ValueError):
        conv2d(x, w, b, c_block=5)


def test_conv2d_rejects_channel_mismatch():
    rng = np.random.default_rng(4)
    x = _rand(rng, (1, 5, 5, 6), jnp.float32)
    w = _rand(rng, (3, 3, 4, 4), jnp.float32)
    b = _rand(rng, (4,), jnp.float32)
    with pytest.raises(ValueError):
        conv2d(x, w, b)


def test_conv2d_rejects_misaligned_stride():
    rng = np.random.default_rng(5)
    x = _rand(rng, (1, 6, 6, 3), jnp.float32)  # (6-3) % 2 != 0
    w = _rand(rng, (3, 3, 3, 4), jnp.float32)
    b = _rand(rng, (4,), jnp.float32)
    with pytest.raises(ValueError):
        conv2d(x, w, b, stride=2)


def test_conv2d_relu_clamps_negative():
    """With a large negative bias everything must clamp to exactly zero."""
    rng = np.random.default_rng(6)
    x = _rand(rng, (1, 5, 5, 3), jnp.float32)
    w = _rand(rng, (3, 3, 3, 4), jnp.float32)
    b = jnp.full((4,), -1e6, jnp.float32)
    got = conv2d(x, w, b, apply_relu=True)
    assert np.all(np.asarray(got) == 0.0)


# ---------------------------------------------------------------------------
# linear
# ---------------------------------------------------------------------------

linear_cases = st.tuples(
    st.integers(1, 4),  # N
    st.sampled_from([1, 3, 16, 48, 96, 512]),  # K
    st.sampled_from([1, 10, 48, 96]),  # M
)


@settings(max_examples=25, deadline=None)
@given(linear_cases, st.booleans())
def test_linear_matches_ref(case, apply_relu):
    n, k, m = case
    rng = np.random.default_rng(hash(case) % 2**32)
    x = _rand(rng, (n, k), jnp.float32)
    w = _rand(rng, (k, m), jnp.float32)
    b = _rand(rng, (m,), jnp.float32)
    got = linear(x, w, b, apply_relu=apply_relu)
    want = linear_ref(x, w, b, apply_relu=apply_relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("kb,mb", [(1, 1), (4, 2), (8, 8), (2, 8)])
def test_linear_block_overrides(kb, mb):
    rng = np.random.default_rng(13)
    x = _rand(rng, (2, 8), jnp.float32)
    w = _rand(rng, (8, 8), jnp.float32)
    b = _rand(rng, (8,), jnp.float32)
    got = linear(x, w, b, k_block=kb, m_block=mb)
    want = linear_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_linear_rejects_mismatch():
    rng = np.random.default_rng(14)
    x = _rand(rng, (2, 8), jnp.float32)
    w = _rand(rng, (9, 8), jnp.float32)
    b = _rand(rng, (8,), jnp.float32)
    with pytest.raises(ValueError):
        linear(x, w, b)
