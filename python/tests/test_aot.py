"""AOT pipeline: lowering produces loadable HLO text and a consistent manifest.

Uses a single small prefix per network to keep lowering time bounded; the
full artifact set is exercised by `make artifacts` + the Rust integration
tests (rust/tests/runtime_integration.rs).
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import emit_network, lower_fn
from compile.model import NETWORKS

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("name", sorted(NETWORKS))
def test_lower_prefix_is_hlo_text(name):
    net = NETWORKS[name]()
    text = lower_fn(net.prefix_fn(1), net.input_shape)
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True: the computation root must be a tuple type.
    assert "->(" in text.replace(" ", "").splitlines()[0]


def test_lowered_hlo_embeds_weights():
    """Weights ride along as constants — no runtime weight files needed."""
    net = NETWORKS["tiny_alexnet"]()
    text = lower_fn(net.prefix_fn(1), net.input_shape)
    assert "constant" in text
    # Regression: the default HLO printer elides large literals as
    # "constant({...})", which the XLA text parser reads back as ZEROS.
    # aot.to_hlo_text must print them in full.
    assert "constant({...})" not in text
    assert "{...}" not in text


def test_emit_network_manifest(tmp_path):
    net = NETWORKS["tiny_squeezenet"]()
    entry = emit_network(net, tmp_path)
    n = len(net.layers)
    assert len(entry["artifacts"]["prefix"]) == n
    assert len(entry["artifacts"]["suffix"]) == n
    assert len(entry["layers"]) == n
    for rec in entry["layers"]:
        assert len(rec["out_shape"]) in (2, 4)
    # every referenced artifact exists and is HLO text
    for kind in ("prefix", "suffix"):
        for fname in entry["artifacts"][kind].values():
            assert (tmp_path / fname).read_text().startswith("HloModule")
    # manifest round-trips through json
    json.loads(json.dumps(entry))


def test_manifest_shapes_match_eval(tmp_path):
    net = NETWORKS["tiny_squeezenet"]()
    entry = emit_network(net, tmp_path)
    shapes = net.layer_shapes()
    got = [tuple(rec["out_shape"]) for rec in entry["layers"]]
    assert got == shapes
