"""L2 correctness: network structure, prefix/suffix composition, sparsity.

The key invariant for the partitioner is that for every split L,
``suffix_L(prefix_L(x)) == forward(x)`` — the client/cloud decomposition is
lossless. Also checks the ReLU-sparsity property Fig. 10 of the paper relies
on (intermediate activations are substantially sparse, with low variance).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import NETWORKS

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module", params=sorted(NETWORKS))
def net(request):
    return NETWORKS[request.param]()


def _image(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.random(shape, dtype=np.float32))


def test_forward_shape(net):
    x = _image(net.input_shape)
    out = net.forward(x)
    assert out.shape == (net.input_shape[0], 10)
    assert np.all(np.isfinite(np.asarray(out)))


def test_layer_shapes_monotone_volume(net):
    """Data volume never grows after a pool layer (dimensionality reduction)."""
    shapes = net.layer_shapes()
    assert len(shapes) == len(net.layers)
    for i, layer in enumerate(net.layers):
        if layer.kind == "pool" and i > 0:
            assert np.prod(shapes[i]) < np.prod(shapes[i - 1])


@pytest.mark.parametrize("seed", [0, 1])
def test_prefix_suffix_compose(net, seed):
    x = _image(net.input_shape, seed)
    full = np.asarray(net.forward(x))
    for split in range(1, len(net.layers)):
        act = net.prefix_fn(split)(x)[0]
        out = np.asarray(net.suffix_fn(split)(act)[0])
        np.testing.assert_allclose(out, full, rtol=1e-5, atol=1e-5)


def test_suffix_zero_is_full_network(net):
    x = _image(net.input_shape, 2)
    np.testing.assert_allclose(
        np.asarray(net.suffix_fn(0)(x)[0]),
        np.asarray(net.forward(x)),
        rtol=1e-6,
        atol=1e-6,
    )


def test_intermediate_sparsity(net):
    """ReLU layers produce substantially sparse activations (paper Fig. 10)."""
    sparsities = []
    for seed in range(4):
        x = _image(net.input_shape, seed)
        per_layer = []
        for split, layer in enumerate(net.layers, start=1):
            if layer.kind in ("conv", "squeeze", "expand"):
                act = np.asarray(net.prefix_fn(split)(x)[0])
                per_layer.append(float(np.mean(act == 0.0)))
        sparsities.append(per_layer)
    arr = np.array(sparsities)  # (images, relu-layers)
    mu, sigma = arr.mean(axis=0), arr.std(axis=0)
    # He-init ReLU nets: ~half the activations are clamped; per-image
    # variation is small relative to the mean (the paper's key observation).
    assert np.all(mu > 0.2)
    assert np.all(sigma < mu)


def test_macs_and_params_positive(net):
    for layer in net.layers:
        if layer.kind in ("conv", "fc", "squeeze", "expand"):
            assert layer.macs > 0
            assert layer.params > 0
        else:
            assert layer.macs == 0


def test_prefix_split_bounds(net):
    with pytest.raises(ValueError):
        net.prefix_fn(0)
    with pytest.raises(ValueError):
        net.prefix_fn(len(net.layers) + 1)
    with pytest.raises(ValueError):
        net.suffix_fn(len(net.layers))
