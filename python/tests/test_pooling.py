"""L1 correctness: Pallas pooling kernels vs pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import global_avg_pool, maxpool2d
from compile.kernels.ref import maxpool2d_ref

jax.config.update("jax_platform_name", "cpu")


def _rand(rng, shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


pool_cases = st.tuples(
    st.integers(1, 2),  # N
    st.integers(1, 6),  # E (output rows)
    st.integers(1, 6),  # G
    st.sampled_from([1, 3, 4, 16]),  # C
    st.sampled_from([(2, 2), (3, 2), (3, 3), (2, 1)]),  # (window, stride)
)


@settings(max_examples=20, deadline=None)
@given(pool_cases)
def test_maxpool_matches_ref(case):
    n, e, g, c, (win, stride) = case
    h = (e - 1) * stride + win
    w = (g - 1) * stride + win
    rng = np.random.default_rng(hash(case) % 2**32)
    x = _rand(rng, (n, h, w, c))
    got = maxpool2d(x, window=win, stride=stride)
    want = maxpool2d_ref(x, window=win, stride=stride)
    assert got.shape == (n, e, g, c)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("cb", [1, 2, 8])
def test_maxpool_channel_blocks(cb):
    rng = np.random.default_rng(5)
    x = _rand(rng, (1, 8, 8, 8))
    got = maxpool2d(x, c_block=cb)
    want = maxpool2d_ref(x)
    np.testing.assert_allclose(got, want)


def test_maxpool_rejects_bad_block():
    x = jnp.zeros((1, 4, 4, 6), jnp.float32)
    with pytest.raises(ValueError):
        maxpool2d(x, c_block=4)


def test_maxpool_nonoverlapping_matches_overlapping_path():
    # window == stride uses the reshape path; window > stride the slice
    # path. Cross-check both against the oracle on the same data.
    rng = np.random.default_rng(6)
    x = _rand(rng, (1, 9, 9, 4))
    np.testing.assert_allclose(
        maxpool2d(x, window=3, stride=3), maxpool2d_ref(x, window=3, stride=3)
    )
    np.testing.assert_allclose(
        maxpool2d(x, window=3, stride=2), maxpool2d_ref(x, window=3, stride=2)
    )


@settings(max_examples=15, deadline=None)
@given(
    st.integers(1, 3),
    st.integers(1, 10),
    st.integers(1, 10),
    st.sampled_from([1, 2, 10, 64]),
)
def test_gap_matches_mean(n, h, w, c):
    rng = np.random.default_rng(n * 1000 + h * 100 + w * 10)
    x = _rand(rng, (n, h, w, c))
    got = global_avg_pool(x)
    want = jnp.mean(x, axis=(1, 2))
    assert got.shape == (n, c)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
