"""Build-time compile package: L1 Pallas kernels, L2 JAX models, AOT lowering.

Nothing in this package runs on the request path; ``make artifacts`` invokes
``compile.aot`` once to emit HLO text + manifest into ``artifacts/``.
"""
