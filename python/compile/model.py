"""L2: JAX CNN models built on the L1 Pallas kernels.

Defines the two *executable* networks of the repo — ``tiny_alexnet`` and
``tiny_squeezenet`` — miniaturized (32x32 input) versions of the paper's
AlexNet and SqueezeNet-v1.1 topologies. The full-size networks are modeled
*analytically* on the Rust side (``rust/src/cnn``); these Tiny variants are
what the serving coordinator actually executes through PJRT, so that the
client-prefix / cloud-suffix code path is exercised with real numerics.

Weights are deterministic (seeded He init) and are embedded in the lowered
HLO as constants, so the Rust runtime needs no separate weight files: each
``prefix_L`` artifact maps ``image -> activation_L`` and each ``suffix_L``
maps ``activation_L -> logits``.

Layer naming mirrors the paper's figures: ``C*`` conv, ``P*`` pool, ``FC*``
fully connected, ``Fs*``/``Fe*`` squeeze/expand layers of a fire module.
"""

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import conv2d, global_avg_pool, linear, maxpool2d


@dataclasses.dataclass(frozen=True)
class Layer:
    """One partition-candidate layer: a name, a paper 'kind', and its fn."""

    name: str
    kind: str  # "conv" | "pool" | "fc" | "squeeze" | "expand" | "gap"
    fn: Callable  # activation -> activation
    macs: int  # multiply-accumulates in this layer (for the delay model)
    params: int  # number of weights+biases (embedded as HLO constants)


def _he(rng: np.random.Generator, shape, fan_in: int) -> np.ndarray:
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def _conv_layer(
    name: str,
    rng: np.random.Generator,
    r: int,
    s: int,
    c: int,
    f: int,
    *,
    stride: int = 1,
    pad: int = 0,
    relu: bool = True,
    out_hw: Tuple[int, int],
    kind: str = "conv",
) -> Layer:
    """Conv layer closing over He-initialized constant weights."""
    w = _he(rng, (r, s, c, f), r * s * c)
    b = np.zeros((f,), np.float32)

    def fn(x, _w=w, _b=b, _stride=stride, _pad=pad, _relu=relu):
        if _pad:
            x = jnp.pad(x, ((0, 0), (_pad, _pad), (_pad, _pad), (0, 0)))
        return conv2d(x, _w, _b, stride=_stride, apply_relu=_relu)

    e, g = out_hw
    return Layer(name, kind, fn, macs=r * s * c * e * g * f, params=w.size + b.size)


def _pool_layer(name: str, window: int = 2, stride: int = 2) -> Layer:
    def fn(x, _w=window, _s=stride):
        return maxpool2d(x, window=_w, stride=_s)

    return Layer(name, "pool", fn, macs=0, params=0)


def _fc_layer(
    name: str,
    rng: np.random.Generator,
    k: int,
    m: int,
    *,
    relu: bool = True,
    flatten: bool = False,
) -> Layer:
    w = _he(rng, (k, m), k)
    b = np.zeros((m,), np.float32)

    def fn(x, _w=w, _b=b, _relu=relu, _flatten=flatten):
        if _flatten:
            x = x.reshape((x.shape[0], -1))
        return linear(x, _w, _b, apply_relu=_relu)

    return Layer(name, "fc", fn, macs=k * m, params=w.size + b.size)


def _expand_layer(
    name: str,
    rng: np.random.Generator,
    c: int,
    e1: int,
    e3: int,
    hw: Tuple[int, int],
) -> Layer:
    """Fire-module expand: concat(1x1 conv, 3x3 conv) over the squeeze output."""
    w1 = _he(rng, (1, 1, c, e1), c)
    b1 = np.zeros((e1,), np.float32)
    w3 = _he(rng, (3, 3, c, e3), 9 * c)
    b3 = np.zeros((e3,), np.float32)

    def fn(x, _w1=w1, _b1=b1, _w3=w3, _b3=b3):
        o1 = conv2d(x, _w1, _b1, stride=1, apply_relu=True)
        xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
        o3 = conv2d(xp, _w3, _b3, stride=1, apply_relu=True)
        return jnp.concatenate([o1, o3], axis=-1)

    h, w = hw
    macs = c * h * w * e1 + 9 * c * h * w * e3
    return Layer(
        name, "expand", fn, macs=macs, params=w1.size + b1.size + w3.size + b3.size
    )


def _gap_layer(name: str) -> Layer:
    def fn(x):
        return global_avg_pool(x)

    return Layer(name, "gap", fn, macs=0, params=0)


@dataclasses.dataclass(frozen=True)
class Network:
    """A partitionable CNN: an ordered list of layers over a fixed input."""

    name: str
    input_shape: Tuple[int, int, int, int]
    layers: List[Layer]

    # -- execution ---------------------------------------------------------
    def forward(self, x):
        for layer in self.layers:
            x = layer.fn(x)
        return x

    def prefix_fn(self, split: int) -> Callable:
        """Client side: layers 1..split (1-indexed, inclusive)."""
        if not 1 <= split <= len(self.layers):
            raise ValueError(f"split {split} out of range")

        def fn(x):
            for layer in self.layers[:split]:
                x = layer.fn(x)
            return (x,)

        return fn

    def suffix_fn(self, split: int) -> Callable:
        """Cloud side: layers split+1..end. ``split=0`` is the full network."""
        if not 0 <= split < len(self.layers):
            raise ValueError(f"split {split} out of range")

        def fn(x):
            for layer in self.layers[split:]:
                x = layer.fn(x)
            return (x,)

        return fn

    # -- shape metadata ----------------------------------------------------
    def layer_shapes(self) -> List[Tuple[int, ...]]:
        """Output shape of each layer, derived by abstract evaluation."""
        shapes = []
        spec = jax.ShapeDtypeStruct(self.input_shape, jnp.float32)
        for i in range(1, len(self.layers) + 1):
            out = jax.eval_shape(self.prefix_fn(i), spec)[0]
            shapes.append(tuple(out.shape))
        return shapes


def tiny_alexnet(seed: int = 2020) -> Network:
    """AlexNet-shaped 11-layer network for 32x32x3 inputs.

    Mirrors the paper's AlexNet partition candidates
    (C1 P1 C2 P2 C3 C4 C5 P3 FC6 FC7 FC8) at 1/7 spatial scale.
    """
    rng = np.random.default_rng(seed)
    layers = [
        _conv_layer("C1", rng, 5, 5, 3, 16, pad=2, out_hw=(32, 32)),
        _pool_layer("P1"),
        _conv_layer("C2", rng, 5, 5, 16, 32, pad=2, out_hw=(16, 16)),
        _pool_layer("P2"),
        _conv_layer("C3", rng, 3, 3, 32, 64, pad=1, out_hw=(8, 8)),
        _conv_layer("C4", rng, 3, 3, 64, 64, pad=1, out_hw=(8, 8)),
        _conv_layer("C5", rng, 3, 3, 64, 32, pad=1, out_hw=(8, 8)),
        _pool_layer("P3"),
        _fc_layer("FC6", rng, 4 * 4 * 32, 96, flatten=True),
        _fc_layer("FC7", rng, 96, 48),
        _fc_layer("FC8", rng, 48, 10, relu=False),
    ]
    return Network("tiny_alexnet", (1, 32, 32, 3), layers)


def tiny_squeezenet(seed: int = 1611) -> Network:
    """SqueezeNet-v1.1-shaped 12-layer network for 32x32x3 inputs.

    Fire modules appear as squeeze (Fs*) / expand (Fe*) layer pairs, matching
    the paper's SqueezeNet partition-candidate naming (Fig. 11b).
    """
    rng = np.random.default_rng(seed)
    layers = [
        _conv_layer("C1", rng, 3, 3, 3, 16, pad=1, out_hw=(32, 32)),
        _pool_layer("P1"),
        _conv_layer("Fs2", rng, 1, 1, 16, 8, out_hw=(16, 16), kind="squeeze"),
        _expand_layer("Fe2", rng, 8, 16, 16, (16, 16)),
        _pool_layer("P3"),
        _conv_layer("Fs3", rng, 1, 1, 32, 16, out_hw=(8, 8), kind="squeeze"),
        _expand_layer("Fe3", rng, 16, 32, 32, (8, 8)),
        _pool_layer("P5"),
        _conv_layer("Fs4", rng, 1, 1, 64, 16, out_hw=(4, 4), kind="squeeze"),
        _expand_layer("Fe4", rng, 16, 32, 32, (4, 4)),
        _conv_layer("C10", rng, 1, 1, 64, 10, out_hw=(4, 4)),
        _gap_layer("GAP"),
    ]
    return Network("tiny_squeezenet", (1, 32, 32, 3), layers)


NETWORKS: Dict[str, Callable[[], Network]] = {
    "tiny_alexnet": tiny_alexnet,
    "tiny_squeezenet": tiny_squeezenet,
}
