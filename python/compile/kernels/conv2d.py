"""L1 Pallas kernel: tiled 2-D convolution (+ bias + optional ReLU).

The convolution is expressed the way the paper's Eyeriss mapping is expressed,
translated to the TPU memory model (DESIGN.md §4 "Hardware adaptation"):

* the grid is ``(N, F/f_b, C/c_b)`` — the last grid dimension walks the input
  channels exactly like the paper's Z-direction passes (§IV-A, Fig. 5), with
  the output block revisited and *accumulated* across those passes (the
  irreducible-psum traffic of the paper);
* ``f_b`` (filters per pass) plays the role of the paper's ``f_i`` scheduling
  parameter, ``c_b`` plays ``z_i``;
* within a pass the work is an unrolled loop over the R*S filter taps, each
  tap contributing a ``(E*G, c_b) @ (c_b, f_b)`` contraction — an MXU-shaped
  ``dot_general`` over the channel dimension, rather than a GPU-style im2col
  scatter/gather.

The kernel assumes the input is already spatially padded (padding is applied
by the L2 model with ``jnp.pad``), so block index maps stay affine.

Run under ``interpret=True`` always: the CPU PJRT plugin cannot execute
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of ``n`` that is <= ``cap`` (>= 1)."""
    for d in range(min(n, cap), 0, -1):
        if n % d == 0:
            return d
    return 1


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, apply_relu, nc_blocks):
    """One (image, filter-block, channel-block) pass of the convolution.

    ``o_ref`` is revisited across the channel-block grid dimension; psums are
    accumulated in place (the paper's GLB-resident irreducible psums).
    """
    c_idx = pl.program_id(2)

    x = x_ref[...]  # (1, Hp, Wp, c_b), pre-padded
    w = w_ref[...]  # (R, S, c_b, f_b)
    r_taps, s_taps = w.shape[0], w.shape[1]
    e_out, g_out = o_ref.shape[1], o_ref.shape[2]
    c_b = x.shape[3]

    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # Unrolled loop over the R*S filter taps: each tap is a strided spatial
    # slice of the ifmap contracted against one (c_b, f_b) weight slab.
    for r in range(r_taps):
        for s in range(s_taps):
            patch = jax.lax.slice(
                x,
                (0, r, s, 0),
                (1, r + (e_out - 1) * stride + 1, s + (g_out - 1) * stride + 1, c_b),
                (1, stride, stride, 1),
            )  # (1, E, G, c_b)
            tap = jax.lax.dot_general(
                patch.astype(jnp.float32),
                w[r, s].astype(jnp.float32),
                dimension_numbers=(((3,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # (1, E, G, f_b) — MXU-shaped contraction over channels
            acc = acc + tap

    @pl.when(c_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc.astype(o_ref.dtype)

    @pl.when(c_idx == nc_blocks - 1)
    def _finalize():
        out = o_ref[...] + b_ref[...].astype(o_ref.dtype)
        if apply_relu:
            out = jnp.maximum(out, jnp.zeros_like(out))
        o_ref[...] = out


@functools.partial(
    jax.jit,
    static_argnames=("stride", "apply_relu", "f_block", "c_block"),
)
def conv2d(x, w, b, *, stride=1, apply_relu=True, f_block=None, c_block=None):
    """Pallas conv2d over NHWC input / RSCF weights.

    Args:
      x: ``(N, Hp, Wp, C)`` input, already spatially padded.
      w: ``(R, S, C, F)`` filters.
      b: ``(F,)`` bias.
      stride: convolution stride ``U`` (same in both spatial dims).
      apply_relu: fuse the ReLU nonlinearity into the final channel pass.
      f_block / c_block: override the ``f_i`` / ``z_i`` scheduling parameters
        (must divide F / C); defaults follow the paper's priority rule of
        maximizing channels per pass within the block budget.

    Returns:
      ``(N, E, G, F)`` ofmap with ``E = (Hp-R)/U + 1``, ``G = (Wp-S)/U + 1``.
    """
    n, hp, wp, c = x.shape
    r, s, wc, f = w.shape
    if wc != c:
        raise ValueError(f"channel mismatch: ifmap C={c}, filter C={wc}")
    if (hp - r) % stride or (wp - s) % stride:
        raise ValueError("padded input is not stride-aligned with the filter")
    e = (hp - r) // stride + 1
    g = (wp - s) // stride + 1

    # Paper priority rule (i): process the maximum possible channels per pass.
    c_b = c_block if c_block is not None else _largest_divisor_leq(c, 64)
    f_b = f_block if f_block is not None else _largest_divisor_leq(f, 32)
    if c % c_b or f % f_b:
        raise ValueError("f_block/c_block must divide F/C")
    nc_blocks = c // c_b

    kernel = functools.partial(
        _conv_kernel, stride=stride, apply_relu=apply_relu, nc_blocks=nc_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(n, f // f_b, nc_blocks),
        in_specs=[
            pl.BlockSpec((1, hp, wp, c_b), lambda ni, fi, ci: (ni, 0, 0, ci)),
            pl.BlockSpec((r, s, c_b, f_b), lambda ni, fi, ci: (0, 0, ci, fi)),
            pl.BlockSpec((f_b,), lambda ni, fi, ci: (fi,)),
        ],
        out_specs=pl.BlockSpec((1, e, g, f_b), lambda ni, fi, ci: (ni, 0, 0, fi)),
        out_shape=jax.ShapeDtypeStruct((n, e, g, f), x.dtype),
        interpret=True,
    )(x, w, b)
