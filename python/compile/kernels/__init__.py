"""L1: Pallas kernels for the CNN compute hot-spots (conv + FC matmul).

All kernels run under ``interpret=True`` so the lowered HLO is executable on
the CPU PJRT client used by the Rust runtime (see DESIGN.md §4).
"""

from .conv2d import conv2d
from .linear import linear
from .pooling import global_avg_pool, maxpool2d
from . import ref

__all__ = ["conv2d", "linear", "maxpool2d", "global_avg_pool", "ref"]
