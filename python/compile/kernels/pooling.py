"""L1 Pallas kernels: max pooling and global average pooling.

Pool layers are partition candidates in the paper (P*/GAP in Figs. 2/11),
so the executable networks run them as Pallas kernels too — keeping the
whole request-path compute inside L1 kernels lowered into the same HLO.

Max pooling reshapes the VMEM-resident block to expose the window axes and
reduces them (a relayout + vector max on TPU, no gather); GAP is a plain
spatial mean. Channel-blocked grids keep VMEM bounded for wide layers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv2d import _largest_divisor_leq


def _maxpool_kernel(x_ref, o_ref, *, window, stride):
    x = x_ref[...]  # (1, H, W, c_b)
    _, h, w, c = x.shape
    e = (h - window) // stride + 1
    g = (w - window) // stride + 1
    if stride == window:
        # Non-overlapping windows: reshape exposes (window, window) axes.
        x = x[:, : e * window, : g * window, :]
        x = x.reshape(1, e, window, g, window, c)
        o_ref[...] = x.max(axis=(2, 4))
    else:
        # Overlapping windows: max over shifted strided slices.
        acc = None
        for dy in range(window):
            for dx in range(window):
                sl = jax.lax.slice(
                    x,
                    (0, dy, dx, 0),
                    (1, dy + (e - 1) * stride + 1, dx + (g - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
                acc = sl if acc is None else jnp.maximum(acc, sl)
        o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("window", "stride", "c_block"))
def maxpool2d(x, *, window=2, stride=2, c_block=None):
    """Pallas max pooling over NHWC (VALID padding).

    Args:
      x: ``(N, H, W, C)`` input.
      window / stride: square pooling window and stride.
      c_block: channel block override (must divide C).
    """
    n, h, w, c = x.shape
    e = (h - window) // stride + 1
    g = (w - window) // stride + 1
    c_b = c_block if c_block is not None else _largest_divisor_leq(c, 64)
    if c % c_b:
        raise ValueError("c_block must divide C")

    kernel = functools.partial(_maxpool_kernel, window=window, stride=stride)
    return pl.pallas_call(
        kernel,
        grid=(n, c // c_b),
        in_specs=[pl.BlockSpec((1, h, w, c_b), lambda ni, ci: (ni, 0, 0, ci))],
        out_specs=pl.BlockSpec((1, e, g, c_b), lambda ni, ci: (ni, 0, 0, ci)),
        out_shape=jax.ShapeDtypeStruct((n, e, g, c), x.dtype),
        interpret=True,
    )(x)


def _gap_kernel(x_ref, o_ref):
    x = x_ref[...]  # (1, H, W, c_b)
    o_ref[...] = jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("c_block",))
def global_avg_pool(x, *, c_block=None):
    """Pallas global average pooling: ``(N, H, W, C) -> (N, C)``."""
    n, h, w, c = x.shape
    c_b = c_block if c_block is not None else _largest_divisor_leq(c, 128)
    if c % c_b:
        raise ValueError("c_block must divide C")
    return pl.pallas_call(
        _gap_kernel,
        grid=(n, c // c_b),
        in_specs=[pl.BlockSpec((1, h, w, c_b), lambda ni, ci: (ni, 0, 0, ci))],
        out_specs=pl.BlockSpec((1, c_b), lambda ni, ci: (ni, ci)),
        out_shape=jax.ShapeDtypeStruct((n, c), x.dtype),
        interpret=True,
    )(x)
