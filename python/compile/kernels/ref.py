"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel must match its
oracle to float tolerance (pytest + Hypothesis sweeps in python/tests/).
They use only stock jax.lax/jnp primitives, no Pallas.
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, b, *, stride=1, apply_relu=True):
    """Reference conv2d over NHWC input / RSCF weights (input pre-padded)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b.astype(jnp.float32)
    if apply_relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def linear_ref(x, w, b, *, apply_relu=True):
    """Reference fully connected layer."""
    out = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if apply_relu:
        out = jnp.maximum(out, 0.0)
    return out.astype(x.dtype)


def maxpool2d_ref(x, *, window=2, stride=2):
    """Reference max pooling over NHWC."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf if jnp.issubdtype(x.dtype, jnp.floating) else jnp.iinfo(x.dtype).min,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )
