"""L1 Pallas kernel: blocked matmul for the fully connected layers.

FC layers are the paper's other MAC-dominated layer kind (§III-A). The kernel
tiles the ``(N, K) @ (K, M)`` product over ``(K/k_b, M/m_b)`` blocks, keeping
the K-walk as the innermost (accumulating) grid dimension, mirroring the
psum-reduction-first scheduling rule of the paper (§IV-C rule (i)).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .conv2d import _largest_divisor_leq


def _linear_kernel(x_ref, w_ref, b_ref, o_ref, *, apply_relu, nk_blocks):
    k_idx = pl.program_id(1)

    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.float32),
        w_ref[...].astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += acc.astype(o_ref.dtype)

    @pl.when(k_idx == nk_blocks - 1)
    def _finalize():
        out = o_ref[...] + b_ref[...].astype(o_ref.dtype)
        if apply_relu:
            out = jnp.maximum(out, jnp.zeros_like(out))
        o_ref[...] = out


@functools.partial(
    jax.jit, static_argnames=("apply_relu", "k_block", "m_block")
)
def linear(x, w, b, *, apply_relu=True, k_block=None, m_block=None):
    """Pallas fully connected layer: ``relu?(x @ w + b)``.

    Args:
      x: ``(N, K)`` activations.
      w: ``(K, M)`` weights.
      b: ``(M,)`` bias.
      apply_relu: fuse ReLU into the final K pass.
      k_block / m_block: tile-size overrides (must divide K / M).
    """
    n, k = x.shape
    wk, m = w.shape
    if wk != k:
        raise ValueError(f"inner-dim mismatch: x K={k}, w K={wk}")

    k_b = k_block if k_block is not None else _largest_divisor_leq(k, 128)
    m_b = m_block if m_block is not None else _largest_divisor_leq(m, 128)
    if k % k_b or m % m_b:
        raise ValueError("k_block/m_block must divide K/M")
    nk_blocks = k // k_b

    kernel = functools.partial(
        _linear_kernel, apply_relu=apply_relu, nk_blocks=nk_blocks
    )
    return pl.pallas_call(
        kernel,
        grid=(m // m_b, nk_blocks),
        in_specs=[
            pl.BlockSpec((n, k_b), lambda mi, ki: (0, ki)),
            pl.BlockSpec((k_b, m_b), lambda mi, ki: (ki, mi)),
            pl.BlockSpec((m_b,), lambda mi, ki: (mi,)),
        ],
        out_specs=pl.BlockSpec((n, m_b), lambda mi, ki: (0, mi)),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=True,
    )(x, w, b)
