"""AOT lowering: JAX models -> HLO text artifacts + manifest for the Rust runtime.

For every network and every partition candidate ``L`` this emits:

* ``<net>_prefix_<L>.hlo.txt`` — layers ``1..L`` (client side), ``L >= 1``;
  ``prefix_<|L|>`` is the full in-situ (FISC) executable.
* ``<net>_suffix_<L>.hlo.txt`` — layers ``L+1..end`` (cloud side), ``L >= 0``;
  ``suffix_0`` is the full cloud (FCC) executable.
* ``manifest.json`` — shapes, layer metadata, artifact paths; the single
  source of truth the Rust runtime loads (``rust/src/runtime/manifest.rs``).

The interchange format is HLO *text*, not serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md). Lowering goes
through ``mlir_module_to_xla_computation(..., return_tuple=True)``, so every
artifact returns a 1-tuple and the Rust side unwraps with ``to_tuple1``.

Run once via ``make artifacts``; never on the request path.
"""

import argparse
import hashlib
import json
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import NETWORKS, Network


def to_hlo_text(lowered) -> str:
    """Convert a jax ``Lowered`` to XLA HLO text (the Rust-loadable format).

    ``print_large_constants=True`` is essential: the default HLO printer
    elides big literals as ``constant({...})``, which the XLA text parser
    silently reads back as *zeros* — wiping the embedded model weights.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax's HLO printer emits metadata attributes (source_end_line, ...)
    # that the xla_extension 0.5.1 text parser rejects — strip them.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_fn(fn, in_shape) -> str:
    spec = jax.ShapeDtypeStruct(tuple(in_shape), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def emit_network(net: Network, out_dir: pathlib.Path) -> dict:
    """Lower all prefix/suffix executables for one network; return manifest entry."""
    shapes = net.layer_shapes()
    n_layers = len(net.layers)

    entry = {
        "input_shape": list(net.input_shape),
        "dtype": "f32",
        "layers": [
            {
                "name": layer.name,
                "kind": layer.kind,
                "out_shape": list(shapes[i]),
                "macs": layer.macs,
                "params": layer.params,
            }
            for i, layer in enumerate(net.layers)
        ],
        "artifacts": {"prefix": {}, "suffix": {}},
    }

    for split in range(1, n_layers + 1):
        name = f"{net.name}_prefix_{split:02d}.hlo.txt"
        text = lower_fn(net.prefix_fn(split), net.input_shape)
        (out_dir / name).write_text(text)
        entry["artifacts"]["prefix"][str(split)] = name
        print(f"  wrote {name} ({len(text)} chars)")

    for split in range(0, n_layers):
        name = f"{net.name}_suffix_{split:02d}.hlo.txt"
        in_shape = net.input_shape if split == 0 else shapes[split - 1]
        text = lower_fn(net.suffix_fn(split), in_shape)
        (out_dir / name).write_text(text)
        entry["artifacts"]["suffix"][str(split)] = name
        print(f"  wrote {name} ({len(text)} chars)")

    return entry


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact directory")
    parser.add_argument(
        "--nets",
        default=",".join(NETWORKS),
        help="comma-separated network names to lower",
    )
    args = parser.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"format": 1, "networks": {}}
    for name in args.nets.split(","):
        print(f"lowering {name} ...")
        net = NETWORKS[name]()
        manifest["networks"][name] = emit_network(net, out_dir)

    text = json.dumps(manifest, indent=1, sort_keys=True)
    (out_dir / "manifest.json").write_text(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:12]
    print(f"wrote manifest.json (sha256 {digest})")


if __name__ == "__main__":
    main()
