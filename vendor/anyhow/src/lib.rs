//! Offline stand-in for the `anyhow` crate (DESIGN.md §"Offline
//! substitutions"): the build environment has no crates.io access, so this
//! vendored crate implements exactly the API slice the repository uses —
//! a context-chaining [`Error`], the [`Result`] alias, the [`Context`]
//! extension trait for `Result` and `Option`, and the [`anyhow!`]/[`bail!`]
//! macros. No downcasting, no backtraces: causes are captured eagerly as
//! strings (every call site only ever formats them).

use std::fmt;

/// `Result<T, anyhow::Error>`, matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-carrying error: the outermost message plus its cause chain.
pub struct Error {
    /// `chain[0]` is the outermost context, `chain.last()` the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Create from a printable message (what [`anyhow!`] expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap a standard error, capturing its `source()` chain.
    pub fn new<E: std::error::Error>(error: E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }

    /// Attach an outer context message (innermost-last ordering).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The rendered cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root) cause message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, matching anyhow.
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// Mirrors anyhow's blanket conversion. `Error` itself deliberately does NOT
// implement `std::error::Error`, which keeps this impl coherent with the
// reflexive `From<Error> for Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// Context extension for `Result` and `Option`, matching `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with an outer context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built as by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chains_and_renders() {
        let r: Result<()> = Err(io_err()).context("reading config");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing key");
        assert_eq!(Some(3).context("fine").unwrap(), 3);
    }

    #[test]
    fn macros_and_question_mark() {
        fn inner(fail: bool) -> Result<u32> {
            if fail {
                bail!("bad value {}", 7);
            }
            let n: u32 = "42".parse()?; // From<ParseIntError> via blanket impl
            Ok(n)
        }
        assert_eq!(inner(false).unwrap(), 42);
        assert_eq!(format!("{:#}", inner(true).unwrap_err()), "bad value 7");
        let e: Error = anyhow!("plain");
        assert_eq!(e.root_cause(), "plain");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }
}
