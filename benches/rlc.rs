//! Bench: the RLC activation codec — the client's on-request-path hot loop
//! (every partitioned inference encodes an activation tensor before the
//! radio) — plus the per-request JPEG Sparsity-In probe.
//! Target: codec is memory-bandwidth-bound (>100 Melem/s encode).

use neupart::bench::Bencher;
use neupart::compress::jpeg::compress_rgb;
use neupart::compress::rlc;
use neupart::corpus::Corpus;
use neupart::util::rng::Rng;

fn sparse_data(n: usize, sparsity: f64, seed: u64) -> Vec<u16> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            if rng.next_f64() < sparsity {
                0
            } else {
                rng.range_u64(1, 255) as u16
            }
        })
        .collect()
}

fn main() {
    let mut b = Bencher::default();

    // AlexNet P2-sized activation (43k elements) at paper-typical sparsity.
    for sp in [0.5, 0.8] {
        let data = sparse_data(43_264, sp, 42);
        let n = data.len() as u64;
        b.bench_elems(&format!("rlc_encode/43k_sp{sp}"), n, || {
            rlc::encode(&data, 8)
        });
        let enc = rlc::encode(&data, 8);
        b.bench_elems(&format!("rlc_decode/43k_sp{sp}"), n, || {
            rlc::decode(&enc, 8)
        });
    }

    // Large tensor (VGG C1 output scale, 3.2M elements).
    let big = sparse_data(3_211_264, 0.6, 7);
    b.bench_elems("rlc_encode/3.2M_sp0.6", big.len() as u64, || {
        rlc::encode(&big, 8)
    });

    // Quantization (f32 -> u8 codes) ahead of the codec.
    let floats: Vec<f32> = sparse_data(43_264, 0.6, 9)
        .iter()
        .map(|&v| v as f32 / 255.0)
        .collect();
    b.bench_elems("quantize/43k", floats.len() as u64, || {
        rlc::quantize(&floats, 8)
    });

    // The JPEG Sparsity-In probe (per-request runtime cost, Alg. 2 line 1).
    let img = Corpus::imagenet_like(5).image(0);
    b.bench_elems("jpeg_probe/64x64_rgb", (img.w * img.h * 3) as u64, || {
        compress_rgb(&img.pixels, img.w, img.h, 90)
    });

    b.write_csv(std::path::Path::new("results/bench_rlc.csv"))
        .expect("csv");
}
