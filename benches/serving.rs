//! Bench: end-to-end serving through the coordinator over real PJRT
//! executables (requires `make artifacts`). This is the paper's system in
//! steady state — reported as requests/s for the three policies.
//!
//! Skips gracefully (exit 0) when artifacts are missing so `cargo bench`
//! stays green on a fresh checkout.

use std::path::PathBuf;
use std::time::Instant;

use neupart::channel::TransmitEnv;
use neupart::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest};
use neupart::corpus::Corpus;

fn requests(n: usize) -> Vec<InferenceRequest> {
    Corpus::new(32, 32, 11)
        .iter(n)
        .enumerate()
        .map(|(i, img)| InferenceRequest {
            id: i as u64,
            tensor: img.to_f32_nhwc(),
            pixels: img.pixels.clone(),
            width: img.w,
            height: img.h,
            env: None,
            deadline_s: None,
        })
        .collect()
}

fn main() {
    if !PathBuf::from("artifacts/manifest.json").exists() {
        println!("serving bench skipped: run `make artifacts` first");
        return;
    }
    let n = 64;
    println!("serving bench: tiny_alexnet, {n} requests/policy, warm pools\n");
    for (label, force) in [("fcc", Some(0)), ("fisc", Some(11)), ("neupart", None)] {
        let cfg = CoordinatorConfig {
            artifacts_dir: PathBuf::from("artifacts"),
            network: "tiny_alexnet".into(),
            env: TransmitEnv::with_effective_rate(120.0e6, 0.78),
            jpeg_quality: 90,
            cloud_pool: 2,
            workers: 4,
            jitter: 0.0,
            time_scale: 0.0,
            force_split: force,
            warm_splits: (0..=11).collect(),
            batch_max: 8,
            gamma_coherent: true,
            shed_infeasible: true,
            seed: 3,
        };
        let coord = Coordinator::new(cfg).expect("coordinator");
        // One throwaway batch to settle caches, then the measured batch.
        coord.serve(requests(8)).expect("warmup serve");
        let t0 = Instant::now();
        coord.serve(requests(n)).expect("serve");
        let dt = t0.elapsed().as_secs_f64();
        let m = coord.metrics.snapshot();
        println!(
            "serve/{label:<8} {:>8.1} req/s   mean latency {:>8.3} ms   mean E_cost {:.4} mJ",
            n as f64 / dt,
            m.mean_latency().as_secs_f64() * 1e3,
            m.mean_e_cost_j() * 1e3
        );
    }
}
