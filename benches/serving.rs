//! Bench: end-to-end serving through the coordinator — the paper's system
//! in steady state, reported as requests/s for the three policies — plus
//! the failure path: a serve under pinned outage (every request completes
//! through the FISC fallback) and a serve under heavy transfer drops
//! (retry/backoff overhead).
//!
//! Runs over real PJRT executables when `make artifacts` has been run;
//! otherwise falls back to the deterministic sim backend so the bench
//! (and the CI smoke run) always measures the full coordinator path.
//!
//! Also drives the million-client load harness through a sharded
//! [`ServingTier`] (always on the hermetic sim backend): the Table-IV
//! device fleet, admission-to-decision latency percentiles, a same-seed
//! determinism double-run, and a single-shard vs multi-shard admission
//! speedup.
//!
//! Also drives the dynamic-channel scenario path (always on the sim
//! backend): a link that dies mid-prefix must fire a mid-flight
//! re-decision with a positive modeled saving over the frozen-γ plan,
//! a link grazing a breakpoint must be absorbed by the hysteresis band,
//! and the per-sample cost of the scenario clock is timed.
//!
//! Also prices the health plane (always on the sim backend): the
//! breaker's trip→reopen recovery latency through a cloud-pool
//! replacement, the brownout shed rate of an open burst vs a clean
//! closed-loop run (which must shed nothing), and the drift watchdog's
//! detection/calibration under an injected 2× model skew.
//!
//! Also boots a cold tier from the v3 binary fleet blob and serves the
//! determinism workload through it, pricing exactly the blob-open step
//! a restarting coordinator pays before admitting traffic
//! (`cold_restart_boot_ns`, `cold_restart_blob_bytes`).
//!
//! Emits machine-readable `results/BENCH_serving.json`
//! (`clean_serve_ns`, `fallback_fisc_ns`, `retry_overhead_ns`,
//! `loadgen_p50_ns`/`p99_ns`/`p999_ns`, `throughput_rps`, `shed_rate`,
//! `shard_count`, `lane_occupancy`, `loadgen_deterministic`,
//! `shard_speedup_admission`, `redecisions_fired`,
//! `redecisions_suppressed`, `energy_delta_vs_frozen_j`,
//! `scenario_step_ns`, `breaker_trip_to_reopen_s`,
//! `brownout_shed_rate`, `drift_detect_requests`,
//! `calibration_factor`, `cold_restart_boot_ns`,
//! `cold_restart_blob_bytes`) and mirrors it to the repo-root
//! `BENCH_serving.json` committed with each PR.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use neupart::channel::{
    FaultConfig, MarkovFadingScenario, MarkovOutage, ScenarioConfig, ScenarioModel, TracePoint,
    TraceScenario, TransmitEnv,
};
use neupart::compress::jpeg::compress_rgb;
use neupart::coordinator::{
    loadgen, ArrivalModel, BrownoutConfig, Coordinator, CoordinatorConfig, ExecutorBackend,
    HealthConfig, InferenceRequest, LoadGenConfig, RedecideConfig, RetryPolicy, ServingTier,
    ServingTierConfig,
};
use neupart::corpus::Corpus;
use neupart::partition::{DelayModel, PolicyRegistry};
use neupart::util::json::Value;

fn requests(n: usize) -> Vec<InferenceRequest> {
    Corpus::new(32, 32, 11)
        .iter(n)
        .enumerate()
        .map(|(i, img)| {
            InferenceRequest::new(i as u64, img.to_f32_nhwc(), img.pixels, img.w, img.h)
        })
        .collect()
}

fn config(backend: ExecutorBackend, force: Option<usize>) -> CoordinatorConfig {
    CoordinatorConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        network: "tiny_alexnet".into(),
        env: TransmitEnv::with_effective_rate(120.0e6, 0.78),
        jpeg_quality: 90,
        cloud_pool: 2,
        workers: 4,
        jitter: 0.0,
        time_scale: 0.0,
        force_split: force,
        warm_splits: (0..=11).collect(),
        batch_max: 8,
        gamma_coherent: true,
        shed_infeasible: true,
        backend,
        faults: None,
        scenario: None,
        redecide: None,
        retry: RetryPolicy::default(),
        health: HealthConfig::default(),
        seed: 3,
    }
}

/// Per-shard config for the load-harness tiers: always the hermetic sim
/// backend, `cloud_pool` trimmed to one thread per shard.
fn shard_config(workers: usize, force: Option<usize>) -> CoordinatorConfig {
    let mut cfg = config(ExecutorBackend::Sim, force);
    cfg.workers = workers;
    cfg.cloud_pool = 1;
    cfg
}

/// One shard per device class in `cfg`'s fleet mix.
fn fleet_tier(cfg: &LoadGenConfig) -> ServingTier {
    ServingTier::new(ServingTierConfig::per_class(
        shard_config(2, None),
        &cfg.class_envs(),
    ))
    .expect("tier")
}

/// Transmit power used by the scenario section's synthetic traces.
const SCENARIO_P_TX_W: f64 = 0.78;

/// Deterministic full-range noise pixels: JPEG cannot squeeze noise, so
/// the probe volume scales with the pixel count.
fn noise_pixels(dim: usize) -> Vec<f64> {
    let mut state: u64 = 0xC0FFEE | 1;
    (0..dim * dim * 3)
        .map(|_| {
            state = state
                .wrapping_mul(6364136223846793005)
                .wrapping_add(1442695040888963407);
            ((state >> 33) & 0xff) as f64
        })
        .collect()
}

/// One measured serve of `n` requests; returns mean ns/request.
fn timed_serve(coord: &Coordinator, n: usize) -> f64 {
    let t0 = Instant::now();
    let outcomes = coord.serve(requests(n)).expect("serve");
    assert_eq!(outcomes.len(), n);
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    let backend = if PathBuf::from("artifacts/manifest.json").exists() {
        ExecutorBackend::Pjrt
    } else {
        println!("no artifacts: serving bench runs on the sim backend\n");
        ExecutorBackend::Sim
    };
    let smoke = std::env::var_os("NEUPART_BENCH_SMOKE").is_some();
    let n = if smoke { 16 } else { 64 };
    println!("serving bench: tiny_alexnet, {n} requests/policy, warm pools\n");
    for (label, force) in [("fcc", Some(0)), ("fisc", Some(11)), ("neupart", None)] {
        let coord = Coordinator::new(config(backend, force)).expect("coordinator");
        // One throwaway batch to settle caches, then the measured batch.
        coord.serve(requests(8)).expect("warmup serve");
        let per_req_ns = timed_serve(&coord, n);
        let m = coord.metrics.snapshot();
        println!(
            "serve/{label:<8} {:>8.1} req/s   mean latency {:>8.3} ms   mean E_cost {:.4} mJ",
            1e9 / per_req_ns,
            m.mean_latency().as_secs_f64() * 1e3,
            m.mean_e_cost_j() * 1e3
        );
    }

    // The failure path. Clean baseline first (NeuPart policy, no faults).
    let clean = Coordinator::new(config(backend, None)).expect("coordinator");
    clean.serve(requests(8)).expect("warmup serve");
    let clean_serve_ns = timed_serve(&clean, n);

    // Pinned outage: the link goes down on the first Markov step and
    // never recovers, so every request resolves through the FISC
    // fallback — this prices the degraded arm end-to-end.
    let mut outage_cfg = config(backend, None);
    outage_cfg.faults = Some(FaultConfig {
        drop_prob: 0.0,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: Some(MarkovOutage {
            p_up_to_down: 1.0,
            p_down_to_up: 0.0,
        }),
        seed: 77,
    });
    outage_cfg.retry = RetryPolicy {
        max_attempts: 2,
        ..RetryPolicy::default()
    };
    let outage = Coordinator::new(outage_cfg).expect("coordinator");
    let fallback_fisc_ns = timed_serve(&outage, n);
    let m = outage.metrics.snapshot();
    assert_eq!(m.fallback_fisc, n as u64, "outage serve must all fall back");
    println!(
        "serve/fallback_fisc {:>8.1} req/s   ({} FISC fallbacks, {} outage rejections)",
        1e9 / fallback_fisc_ns,
        m.fallback_fisc,
        m.outage_rejections
    );

    // Heavy transfer drops with enough retry budget to still succeed:
    // the per-request delta over the clean baseline is the retry/backoff
    // overhead (clamped at 0 — scheduling noise can make the faulty run
    // measure faster on tiny workloads).
    let mut drops_cfg = config(backend, None);
    drops_cfg.faults = Some(FaultConfig {
        drop_prob: 0.4,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: None,
        seed: 78,
    });
    drops_cfg.retry = RetryPolicy {
        max_attempts: 16,
        ..RetryPolicy::default()
    };
    let drops = Coordinator::new(drops_cfg).expect("coordinator");
    let drops_serve_ns = timed_serve(&drops, n);
    let retry_overhead_ns = (drops_serve_ns - clean_serve_ns).max(0.0);
    let m = drops.metrics.snapshot();
    println!(
        "serve/drops         {:>8.1} req/s   ({} retries, {:.4} mJ wasted, overhead {:.0} ns/req)",
        1e9 / drops_serve_ns,
        m.retries_total,
        m.wasted_retry_energy_j * 1e3,
        retry_overhead_ns
    );

    // ---- Dynamic channel scenarios: mid-flight re-decision ----
    // Always the hermetic sim backend: this section measures the
    // scenario/re-decision path, not the kernels. Both traces are built
    // from the *measured* envelope (breakpoints, first-segment winner,
    // layer latencies), so the asserts survive energy-model retuning.
    let probe = Coordinator::new(config(ExecutorBackend::Sim, None)).expect("coordinator");
    let bps = probe.partitioner().envelope().breakpoints().to_vec();
    assert!(!bps.is_empty(), "tiny_alexnet envelope has no breakpoints");
    let w_lo = probe.partitioner().envelope().segments()[0].split;
    let lat0 = DelayModel::from_profile(probe.profile()).client_latencies_s()[0];
    let gamma_adm = bps[0] / 1.3;
    // Grazing γ: past the first breakpoint but inside both the 1.5×
    // hysteresis band and the second segment.
    let gamma_osc = if bps.len() >= 2 {
        (bps[0] * 1.3).min((bps[0] * bps[1]).sqrt())
    } else {
        bps[0] * 1.3
    };
    assert!(gamma_osc > bps[0] && gamma_osc < bps[0] * 1.5);
    // A probe large enough that admission lands on the envelope winner
    // rather than FCC (a full-input upload would dodge the walk).
    let adm_env = TransmitEnv::with_effective_rate(SCENARIO_P_TX_W / gamma_adm, SCENARIO_P_TX_W);
    let (pixels, dim) = [192usize, 384, 768]
        .into_iter()
        .map(|dim| (noise_pixels(dim), dim))
        .find(|(px, dim)| {
            let bits = compress_rgb(px, *dim, *dim, 90).bits as f64;
            let pt = probe.partitioner();
            let fcc = pt.candidate_cost_j(0, bits, &adm_env);
            fcc > 1.5 * pt.candidate_cost_j(w_lo, bits, &adm_env)
        })
        .expect("no probe large enough to exclude FCC");
    drop(probe);

    let plateau = |t_s: f64, gamma: f64| TracePoint {
        t_s,
        rate_bps: SCENARIO_P_TX_W / gamma,
        p_tx_w: SCENARIO_P_TX_W,
    };
    let scenario_serve = |trace: TraceScenario, margin: f64| {
        let mut cfg = config(ExecutorBackend::Sim, None);
        cfg.scenario = Some(ScenarioConfig::Trace(trace));
        cfg.redecide = Some(RedecideConfig { hysteresis_margin: margin });
        let coord = Coordinator::new(cfg).expect("coordinator");
        let img = Corpus::new(32, 32, 11).iter(1).next().expect("image");
        let req = InferenceRequest::new(0, img.to_f32_nhwc(), pixels.clone(), dim, dim);
        coord.serve(vec![req]).expect("scenario serve");
        coord.metrics.snapshot()
    };

    // The link dies before the first layer boundary (1 bps, far below
    // the channel's effective floor): the walk must move the split.
    let fade = TraceScenario::from_points(vec![
        plateau(0.0, gamma_adm),
        TracePoint {
            t_s: lat0 * 0.5,
            rate_bps: 1.0,
            p_tx_w: SCENARIO_P_TX_W,
        },
    ])
    .expect("fade trace");
    let m_fade = scenario_serve(fade, 0.1);
    assert!(m_fade.redecisions_fired >= 1, "dead link must fire a re-decision");
    assert!(
        m_fade.energy_delta_vs_frozen_j > 0.0,
        "re-decision must model a saving over frozen γ"
    );
    println!(
        "\nscenario/fade       fired {} re-decision(s), modeled saving {:.4} mJ vs frozen gamma",
        m_fade.redecisions_fired,
        m_fade.energy_delta_vs_frozen_j * 1e3
    );

    // The link steps just past the first breakpoint, inside the band:
    // hysteresis must hold the split and count the crossing suppressed.
    let graze = TraceScenario::from_points(vec![
        plateau(0.0, gamma_adm),
        plateau(lat0 * 0.5, gamma_osc),
    ])
    .expect("graze trace");
    let m_graze = scenario_serve(graze, 0.5);
    assert!(
        m_graze.redecisions_suppressed >= 1,
        "grazing link must record a suppressed crossing"
    );
    assert_eq!(m_graze.redecisions_fired, 0, "hysteresis must hold the split");
    println!(
        "scenario/graze      {} crossing(s) suppressed by hysteresis, split pinned",
        m_graze.redecisions_suppressed
    );

    // Per-sample cost of the scenario clock (Markov LTE regime fading):
    // this is what every layer-boundary check and channel send pays.
    let markov = MarkovFadingScenario::lte(9);
    let steps: u64 = if smoke { 100_000 } else { 1_000_000 };
    let t0 = Instant::now();
    let mut acc = 0.0;
    for i in 0..steps {
        acc += markov.env_at(i as f64 * 1e-3).effective_bit_rate();
    }
    std::hint::black_box(acc);
    let scenario_step_ns = t0.elapsed().as_nanos() as f64 / steps as f64;
    println!("scenario/step       {scenario_step_ns:.1} ns per env_at sample (Markov LTE)");

    // ---- Health plane: breaker recovery, brownout, drift watchdog ----
    // Always the hermetic sim backend: this section prices the recovery
    // machinery, not the kernels.

    // Breaker trip → reopen. Forced-FCC so every request takes the
    // remote path: kill the cloud pool (the shard force-opens on the
    // dead-pool evidence), replace it, and measure how long partitioned
    // serving takes to come back (cooldown + a successful probe).
    let breaker = Coordinator::new(config(ExecutorBackend::Sim, Some(0))).expect("coordinator");
    breaker.serve(requests(8)).expect("warmup serve");
    breaker.kill_cloud_pool();
    let t_trip = Instant::now();
    breaker.serve(requests(4)).expect("tripping serve");
    assert!(
        breaker.metrics.snapshot().degraded_mode_entered >= 1,
        "dead cloud pool must trip the breaker"
    );
    breaker.replace_cloud_pool().expect("replace cloud pool");
    let mut breaker_trip_to_reopen_s = f64::NAN;
    for _ in 0..400 {
        breaker.serve(requests(2)).expect("recovery serve");
        if breaker.metrics.snapshot().breaker_reopened >= 1 {
            breaker_trip_to_reopen_s = t_trip.elapsed().as_secs_f64();
            break;
        }
        // The breaker cools down in wall time; don't outrun it.
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    assert!(
        breaker_trip_to_reopen_s.is_finite(),
        "breaker must reopen after the pool is replaced"
    );
    println!(
        "\nhealth/breaker      trip -> reopen in {:.3} s (pool replaced mid-run)",
        breaker_trip_to_reopen_s
    );

    // Brownout: a clean closed-loop run must shed nothing, an open
    // burst over the same fleet must shed its loose-deadline overload
    // instead of queueing it. Watermarks are pulled low so the verdict
    // does not depend on producer/worker timing margins.
    let brown_n: u64 = if smoke { 20_000 } else { 100_000 };
    let mut brown_cfg = LoadGenConfig::table_iv_wlan(brown_n, 13);
    brown_cfg.infeasible_frac = 0.0;
    let mut brown_shard = shard_config(2, None);
    brown_shard.health.brownout = BrownoutConfig {
        enabled: true,
        soft_watermark: 0.25,
        hard_watermark: 0.5,
        loose_headroom_s: 1.0,
    };
    let brown_tier = |cfg: &LoadGenConfig| {
        ServingTier::new(ServingTierConfig::per_class(
            brown_shard.clone(),
            &cfg.class_envs(),
        ))
        .expect("tier")
    };
    brown_cfg.arrival = ArrivalModel::Closed { concurrency: 2 };
    let clean_rep = loadgen::run(&brown_tier(&brown_cfg), &brown_cfg).expect("clean brownout run");
    assert_eq!(
        clean_rep.shed_overflow + clean_rep.shed_brownout,
        0,
        "clean closed-loop load must not brown out"
    );
    brown_cfg.arrival = ArrivalModel::Burst {
        concurrency: 2,
        producers: 4,
        clean_fraction: 0.2,
    };
    let burst_rep = loadgen::run(&brown_tier(&brown_cfg), &brown_cfg).expect("burst brownout run");
    assert!(
        burst_rep.shed_brownout > 0,
        "open burst must shed via the brownout reason"
    );
    let brownout_shed_rate = (burst_rep.shed_overflow + burst_rep.shed_brownout) as f64
        / burst_rep.clients.max(1) as f64;
    println!(
        "health/brownout     clean shed 0, burst shed {:.1}% ({} brownout / {} overflow), p99 {:.1} us",
        brownout_shed_rate * 100.0,
        burst_rep.shed_brownout,
        burst_rep.shed_overflow,
        burst_rep.p99_ns / 1e3
    );

    // Drift watchdog: forced-FISC (the client prefix is the whole
    // network, so every request observes a residual) under an injected
    // 2× latency+energy skew — every observation detects, the class
    // quarantines past min_samples, and the calibration factor
    // converges onto the skew.
    let drift_n = 32usize;
    let drift = Coordinator::new(config(ExecutorBackend::Sim, Some(11))).expect("coordinator");
    drift.set_model_skew(2.0, 2.0);
    drift.serve(requests(drift_n)).expect("drift serve");
    let m_drift = drift.metrics.snapshot();
    assert_eq!(
        m_drift.drift_detect_requests, drift_n as u64,
        "every skewed request must detect drift"
    );
    assert!(m_drift.drift_quarantines >= 1, "2x skew must quarantine");
    assert!(
        (m_drift.calibration_factor - 2.0).abs() < 0.25,
        "calibration factor must converge onto the injected skew"
    );
    println!(
        "health/drift        {} detections, {} quarantine(s), calibration factor {:.3}",
        m_drift.drift_detect_requests, m_drift.drift_quarantines, m_drift.calibration_factor
    );

    // ---- Load harness: the Table-IV fleet through the sharded tier ----
    // Always the hermetic sim backend, whatever the policy benches above
    // ran on: the harness measures the serving tier, not the kernels.
    let det_clients: u64 = 100_000;
    let lg_clients: u64 = if smoke { det_clients } else { 1_000_000 };
    let mut lg_cfg = LoadGenConfig::table_iv_wlan(lg_clients, 42);
    lg_cfg.arrival = ArrivalModel::Open { producers: 4 };
    let tier = fleet_tier(&lg_cfg);
    let shard_count = tier.shard_count();
    let report = loadgen::run(&tier, &lg_cfg).expect("load run");
    assert_eq!(report.completed + report.shed, report.clients);
    println!(
        "\nloadgen: {} clients over {} shards -> {:.0} req/s, shed {:.2}%",
        report.clients,
        shard_count,
        report.throughput_rps,
        report.shed_rate * 100.0
    );
    println!(
        "loadgen latency (admission->decision): p50 {:.1} us  p99 {:.1} us  p999 {:.1} us",
        report.p50_ns / 1e3,
        report.p99_ns / 1e3,
        report.p999_ns / 1e3
    );

    // Same-seed determinism: the shed set and fallback counts are pure
    // functions of (seed, client id) — two fresh tiers must agree.
    let mut det_cfg = lg_cfg.clone();
    det_cfg.clients = det_clients;
    let det_a = if lg_clients == det_clients {
        report.clone()
    } else {
        loadgen::run(&fleet_tier(&det_cfg), &det_cfg).expect("determinism run a")
    };
    let det_b = loadgen::run(&fleet_tier(&det_cfg), &det_cfg).expect("determinism run b");
    let deterministic = det_a.shed == det_b.shed
        && det_a.ok == det_b.ok
        && det_a.degraded == det_b.degraded
        && det_a.fallback_fisc == det_b.fallback_fisc;
    assert!(deterministic, "same seed must shed and fall back identically");
    println!(
        "loadgen determinism: {} clients, shed {} / fallback {} on both runs",
        det_clients, det_b.shed, det_b.fallback_fisc
    );

    // ---- Cold restart: boot a fresh tier from the v3 fleet blob ----
    // The zero-copy fleet artifact end-to-end: a cold ServingTier
    // restart opens the blob with one header+checksum validation (no
    // per-entry JSON parse, no engine builds beyond the shard keys) and
    // then serves the same load — `boot_ns` prices exactly the
    // blob-open step a restarting coordinator pays before admitting
    // traffic.
    let author = PolicyRegistry::new();
    for env in det_cfg.class_envs() {
        author
            .get_or_build("tiny_alexnet", &env)
            .expect("author fleet entry");
    }
    let fleet_blob = author.export_v3();
    let cold = loadgen::run_cold_restart(
        ServingTierConfig::per_class(shard_config(2, None), &det_cfg.class_envs()),
        &fleet_blob,
        &det_cfg,
    )
    .expect("cold restart run");
    assert_eq!(cold.report.completed + cold.report.shed, cold.report.clients);
    assert_eq!(cold.fleet_entries, det_cfg.mix.len());
    let cold_restart_boot_ns = cold.boot_ns as f64;
    println!(
        "cold restart: {} fleet entries ({} bytes) booted in {:.1} us, then {:.0} req/s",
        cold.fleet_entries,
        cold.blob_bytes,
        cold_restart_boot_ns / 1e3,
        cold.report.throughput_rps
    );

    // Single-shard vs multi-shard admission throughput, same per-shard
    // resources (1 worker, 1-thread executors) and a forced-FISC workload
    // so each shard serializes on its own client executor: the shard
    // count is the only variable.
    let speed_n: u64 = if smoke { 20_000 } else { 100_000 };
    let mut speed_cfg = LoadGenConfig::table_iv_wlan(speed_n, 7);
    speed_cfg.arrival = ArrivalModel::Open { producers: 4 };
    speed_cfg.infeasible_frac = 0.0;
    speed_cfg.mix = vec![(0.78, 1.0), (0.85, 1.0), (1.14, 1.0), (1.28, 1.0)];
    let single =
        ServingTier::new(ServingTierConfig::single(shard_config(1, Some(11)))).expect("tier");
    let single_rep = loadgen::run(&single, &speed_cfg).expect("single-shard run");
    drop(single);
    let multi = ServingTier::new(ServingTierConfig::per_class(
        shard_config(1, Some(11)),
        &speed_cfg.class_envs(),
    ))
    .expect("tier");
    let multi_rep = loadgen::run(&multi, &speed_cfg).expect("multi-shard run");
    drop(multi);
    let shard_speedup = multi_rep.throughput_rps / single_rep.throughput_rps.max(f64::MIN_POSITIVE);
    println!(
        "shard speedup: 1 shard {:.0} req/s vs {} shards {:.0} req/s -> {:.2}x",
        single_rep.throughput_rps,
        speed_cfg.mix.len(),
        multi_rep.throughput_rps,
        shard_speedup
    );

    let lanes: BTreeMap<String, Value> = report
        .lane_occupancy
        .iter()
        .map(|(lane, batches)| (lane.to_string(), Value::Num(*batches as f64)))
        .collect();

    let mut b = neupart::bench::Bencher::from_env();
    // Record the serve timings through the Bencher's results array too, so
    // the JSON carries the standard shape alongside the top-level keys.
    b.results.push(neupart::bench::BenchResult {
        name: "serve_clean_per_request".to_string(),
        mean_ns: clean_serve_ns,
        std_ns: 0.0,
        min_ns: clean_serve_ns,
        iters: n as u64,
        samples: 1,
        elems: None,
    });
    let extras = vec![
        (
            "backend".to_string(),
            Value::Str(format!("{backend:?}").to_lowercase()),
        ),
        ("requests".to_string(), Value::Num(n as f64)),
        ("clean_serve_ns".to_string(), Value::Num(clean_serve_ns)),
        ("fallback_fisc_ns".to_string(), Value::Num(fallback_fisc_ns)),
        ("retry_overhead_ns".to_string(), Value::Num(retry_overhead_ns)),
        (
            "loadgen_clients".to_string(),
            Value::Num(report.clients as f64),
        ),
        ("loadgen_p50_ns".to_string(), Value::Num(report.p50_ns)),
        ("loadgen_p99_ns".to_string(), Value::Num(report.p99_ns)),
        ("loadgen_p999_ns".to_string(), Value::Num(report.p999_ns)),
        (
            "throughput_rps".to_string(),
            Value::Num(report.throughput_rps),
        ),
        ("shed_rate".to_string(), Value::Num(report.shed_rate)),
        ("shard_count".to_string(), Value::Num(shard_count as f64)),
        ("lane_occupancy".to_string(), Value::Obj(lanes)),
        (
            "loadgen_deterministic".to_string(),
            Value::Bool(deterministic),
        ),
        (
            "shard_speedup_admission".to_string(),
            Value::Num(shard_speedup),
        ),
        (
            "redecisions_fired".to_string(),
            Value::Num(m_fade.redecisions_fired as f64),
        ),
        (
            "redecisions_suppressed".to_string(),
            Value::Num(m_graze.redecisions_suppressed as f64),
        ),
        (
            "energy_delta_vs_frozen_j".to_string(),
            Value::Num(m_fade.energy_delta_vs_frozen_j),
        ),
        (
            "scenario_step_ns".to_string(),
            Value::Num(scenario_step_ns),
        ),
        (
            "breaker_trip_to_reopen_s".to_string(),
            Value::Num(breaker_trip_to_reopen_s),
        ),
        (
            "brownout_shed_rate".to_string(),
            Value::Num(brownout_shed_rate),
        ),
        (
            "drift_detect_requests".to_string(),
            Value::Num(m_drift.drift_detect_requests as f64),
        ),
        (
            "calibration_factor".to_string(),
            Value::Num(m_drift.calibration_factor),
        ),
        (
            "cold_restart_boot_ns".to_string(),
            Value::Num(cold_restart_boot_ns),
        ),
        (
            "cold_restart_blob_bytes".to_string(),
            Value::Num(cold.blob_bytes as f64),
        ),
    ];
    // Written twice: under results/ (the CI artifact convention) and at
    // the repo root, where the committed copy records the perf
    // trajectory PR over PR.
    b.write_json(std::path::Path::new("results/BENCH_serving.json"), extras.clone())
        .expect("json");
    b.write_json(std::path::Path::new("BENCH_serving.json"), extras)
        .expect("json");
    println!("wrote results/BENCH_serving.json and BENCH_serving.json");
}
