//! Bench: end-to-end serving through the coordinator — the paper's system
//! in steady state, reported as requests/s for the three policies — plus
//! the failure path: a serve under pinned outage (every request completes
//! through the FISC fallback) and a serve under heavy transfer drops
//! (retry/backoff overhead).
//!
//! Runs over real PJRT executables when `make artifacts` has been run;
//! otherwise falls back to the deterministic sim backend so the bench
//! (and the CI smoke run) always measures the full coordinator path.
//!
//! Emits machine-readable `results/BENCH_serving.json`
//! (`clean_serve_ns`, `fallback_fisc_ns`, `retry_overhead_ns`).

use std::path::PathBuf;
use std::time::Instant;

use neupart::channel::{FaultConfig, MarkovOutage, TransmitEnv};
use neupart::coordinator::{
    Coordinator, CoordinatorConfig, ExecutorBackend, InferenceRequest, RetryPolicy,
};
use neupart::corpus::Corpus;
use neupart::util::json::Value;

fn requests(n: usize) -> Vec<InferenceRequest> {
    Corpus::new(32, 32, 11)
        .iter(n)
        .enumerate()
        .map(|(i, img)| InferenceRequest {
            id: i as u64,
            tensor: img.to_f32_nhwc(),
            pixels: img.pixels.clone(),
            width: img.w,
            height: img.h,
            env: None,
            deadline_s: None,
        })
        .collect()
}

fn config(backend: ExecutorBackend, force: Option<usize>) -> CoordinatorConfig {
    CoordinatorConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        network: "tiny_alexnet".into(),
        env: TransmitEnv::with_effective_rate(120.0e6, 0.78),
        jpeg_quality: 90,
        cloud_pool: 2,
        workers: 4,
        jitter: 0.0,
        time_scale: 0.0,
        force_split: force,
        warm_splits: (0..=11).collect(),
        batch_max: 8,
        gamma_coherent: true,
        shed_infeasible: true,
        backend,
        faults: None,
        retry: RetryPolicy::default(),
        seed: 3,
    }
}

/// One measured serve of `n` requests; returns mean ns/request.
fn timed_serve(coord: &Coordinator, n: usize) -> f64 {
    let t0 = Instant::now();
    let outcomes = coord.serve(requests(n)).expect("serve");
    assert_eq!(outcomes.len(), n);
    t0.elapsed().as_nanos() as f64 / n as f64
}

fn main() {
    let backend = if PathBuf::from("artifacts/manifest.json").exists() {
        ExecutorBackend::Pjrt
    } else {
        println!("no artifacts: serving bench runs on the sim backend\n");
        ExecutorBackend::Sim
    };
    let smoke = std::env::var_os("NEUPART_BENCH_SMOKE").is_some();
    let n = if smoke { 16 } else { 64 };
    println!("serving bench: tiny_alexnet, {n} requests/policy, warm pools\n");
    for (label, force) in [("fcc", Some(0)), ("fisc", Some(11)), ("neupart", None)] {
        let coord = Coordinator::new(config(backend, force)).expect("coordinator");
        // One throwaway batch to settle caches, then the measured batch.
        coord.serve(requests(8)).expect("warmup serve");
        let per_req_ns = timed_serve(&coord, n);
        let m = coord.metrics.snapshot();
        println!(
            "serve/{label:<8} {:>8.1} req/s   mean latency {:>8.3} ms   mean E_cost {:.4} mJ",
            1e9 / per_req_ns,
            m.mean_latency().as_secs_f64() * 1e3,
            m.mean_e_cost_j() * 1e3
        );
    }

    // The failure path. Clean baseline first (NeuPart policy, no faults).
    let clean = Coordinator::new(config(backend, None)).expect("coordinator");
    clean.serve(requests(8)).expect("warmup serve");
    let clean_serve_ns = timed_serve(&clean, n);

    // Pinned outage: the link goes down on the first Markov step and
    // never recovers, so every request resolves through the FISC
    // fallback — this prices the degraded arm end-to-end.
    let mut outage_cfg = config(backend, None);
    outage_cfg.faults = Some(FaultConfig {
        drop_prob: 0.0,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: Some(MarkovOutage {
            p_up_to_down: 1.0,
            p_down_to_up: 0.0,
        }),
        seed: 77,
    });
    outage_cfg.retry = RetryPolicy {
        max_attempts: 2,
        ..RetryPolicy::default()
    };
    let outage = Coordinator::new(outage_cfg).expect("coordinator");
    let fallback_fisc_ns = timed_serve(&outage, n);
    let m = outage.metrics.snapshot();
    assert_eq!(m.fallback_fisc, n as u64, "outage serve must all fall back");
    println!(
        "serve/fallback_fisc {:>8.1} req/s   ({} FISC fallbacks, {} outage rejections)",
        1e9 / fallback_fisc_ns,
        m.fallback_fisc,
        m.outage_rejections
    );

    // Heavy transfer drops with enough retry budget to still succeed:
    // the per-request delta over the clean baseline is the retry/backoff
    // overhead (clamped at 0 — scheduling noise can make the faulty run
    // measure faster on tiny workloads).
    let mut drops_cfg = config(backend, None);
    drops_cfg.faults = Some(FaultConfig {
        drop_prob: 0.4,
        stall_prob: 0.0,
        stall_max_factor: 0.0,
        outage: None,
        seed: 78,
    });
    drops_cfg.retry = RetryPolicy {
        max_attempts: 16,
        ..RetryPolicy::default()
    };
    let drops = Coordinator::new(drops_cfg).expect("coordinator");
    let drops_serve_ns = timed_serve(&drops, n);
    let retry_overhead_ns = (drops_serve_ns - clean_serve_ns).max(0.0);
    let m = drops.metrics.snapshot();
    println!(
        "serve/drops         {:>8.1} req/s   ({} retries, {:.4} mJ wasted, overhead {:.0} ns/req)",
        1e9 / drops_serve_ns,
        m.retries_total,
        m.wasted_retry_energy_j * 1e3,
        retry_overhead_ns
    );

    let mut b = neupart::bench::Bencher::from_env();
    // Record the serve timings through the Bencher's results array too, so
    // the JSON carries the standard shape alongside the top-level keys.
    b.results.push(neupart::bench::BenchResult {
        name: "serve_clean_per_request".to_string(),
        mean_ns: clean_serve_ns,
        std_ns: 0.0,
        min_ns: clean_serve_ns,
        iters: n as u64,
        samples: 1,
        elems: None,
    });
    b.write_json(
        std::path::Path::new("results/BENCH_serving.json"),
        vec![
            (
                "backend".to_string(),
                Value::Str(format!("{backend:?}").to_lowercase()),
            ),
            ("requests".to_string(), Value::Num(n as f64)),
            ("clean_serve_ns".to_string(), Value::Num(clean_serve_ns)),
            ("fallback_fisc_ns".to_string(), Value::Num(fallback_fisc_ns)),
            ("retry_overhead_ns".to_string(), Value::Num(retry_overhead_ns)),
        ],
    )
    .expect("json");
    println!("wrote results/BENCH_serving.json");
}
