//! Bench: the runtime partition decision (paper Alg. 2) — the O(|L|)
//! linear scan (with its per-call cost-vector allocation) against the
//! precomputed lower-envelope engine and its batched serving path, all
//! through the one public surface, the `PartitionPolicy` trait.
//!
//! The paper's claim is that Alg. 2's overhead is "virtually zero"; the
//! envelope engine makes that literal: `EnergyPolicy::decide` is a
//! breakpoint binary search plus one FCC comparison, and `decide_batch`
//! amortizes the envelope candidates over a whole batch. Emits the
//! criterion-style lines plus `results/bench_partitioner.csv` and the
//! machine-readable `results/BENCH_partition.json` (per-network
//! ns/decision, decisions/s and speedups) so the perf trajectory is
//! tracked across PRs. The registry section measures the fleet surface:
//! shared-entry lookup, v2 artifact size (`table_v2_bytes`) and — the
//! PR-5 regression guard — SLO decisions answered from an **imported**
//! fleet's shared engines (`slo_from_import_ns`): if a v2 import ever
//! stops reconstructing its SLO engine, this bench aborts and CI fails.
//!
//! Set `NEUPART_BENCH_SMOKE=1` for the CI smoke run (shorter budgets).

use std::collections::BTreeMap;

use neupart::bench::Bencher;
use neupart::channel::TransmitEnv;
use neupart::cnn::Network;
use neupart::cnnergy::CnnErgy;
use neupart::partition::{
    decide_with_slo_scan, device_class, DecisionContext, DelayModel, EnergyPolicy, EnvelopeTable,
    PartitionPolicy, Partitioner, PolicyRegistry, SloPartitioner, SloPolicy, FCC,
};
use neupart::util::json::Value;

const BATCH: usize = 1024;

/// SLO cycle for the constrained benches: loose (unconstrained optimum
/// feasible — the O(log L) hot path), binding (frontier walk), and
/// infeasible (delay-envelope fallback).
const SLO_CYCLE_S: [f64; 3] = [0.5, 0.012, 1e-6];

fn main() {
    let mut b = Bencher::from_env();
    let model = CnnErgy::inference_8bit();
    let env = TransmitEnv::paper_default();

    let mut summary = BTreeMap::new();
    for net in Network::paper_networks() {
        let p = Partitioner::new(&net, &model);
        let policy = EnergyPolicy::new(p.clone());

        // Baseline: the linear scan with a fresh cost vector per decision
        // (`decide_detailed`, the pre-envelope hot path). Sparsity varies
        // per call so the input volume is not branch-predictable.
        let mut sp = 0.40;
        let scan_ns = b
            .bench(&format!("alg2_scan/{}", net.name), || {
                sp = if sp > 0.9 { 0.40 } else { sp + 0.001 };
                policy.decide_detailed(&DecisionContext::from_sparsity(&p, sp, env))
            })
            .mean_ns;

        // Envelope engine through the trait: O(log segments) + one FCC
        // comparison — what the serving coordinator calls. (There is no
        // separate `decide_fast` entry point anymore; the trait path IS
        // the envelope path, so this is the one envelope measurement.)
        let mut sp_p = 0.40;
        let policy_ns = b
            .bench(&format!("policy_decide/{}", net.name), || {
                sp_p = if sp_p > 0.9 { 0.40 } else { sp_p + 0.001 };
                policy.decide(&DecisionContext::from_sparsity(&p, sp_p, env))
            })
            .mean_ns;

        // Batched path: one envelope evaluation per BATCH requests.
        let input_bits: Vec<f64> = (0..BATCH)
            .map(|i| p.transmit_bits(FCC, 0.40 + 0.55 * i as f64 / BATCH as f64))
            .collect();
        let batch_ctx = DecisionContext::from_input_bits(0.0, env);
        let mut out = Vec::with_capacity(BATCH);
        let batch_ns = b
            .bench_elems(
                &format!("alg2_batch{BATCH}/{}", net.name),
                BATCH as u64,
                || {
                    policy.decide_batch(&input_bits, &batch_ctx, &mut out);
                    out.len()
                },
            )
            .mean_ns
            / BATCH as f64;

        // Constrained (SLO) path: the O(|L|) delay scan (fresh delay + cost
        // vectors per call) against the envelope-backed SloPolicy.
        let dm = DelayModel::new(&net, &model);
        let slo_policy = SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone()));
        let mut sp_s = 0.40;
        let mut slo_i = 0;
        let slo_scan_ns = b
            .bench(&format!("slo_scan/{}", net.name), || {
                sp_s = if sp_s > 0.9 { 0.40 } else { sp_s + 0.001 };
                slo_i = (slo_i + 1) % SLO_CYCLE_S.len();
                decide_with_slo_scan(&p, &dm, sp_s, &env, SLO_CYCLE_S[slo_i])
            })
            .mean_ns;
        let mut sp_f = 0.40;
        let mut slo_j = 0;
        let slo_envelope_ns = b
            .bench(&format!("slo_envelope/{}", net.name), || {
                sp_f = if sp_f > 0.9 { 0.40 } else { sp_f + 0.001 };
                slo_j = (slo_j + 1) % SLO_CYCLE_S.len();
                slo_policy.decide(
                    &DecisionContext::from_sparsity(&p, sp_f, env)
                        .with_slo(SLO_CYCLE_S[slo_j]),
                )
            })
            .mean_ns;

        let mut row = BTreeMap::new();
        row.insert("layers".to_string(), Value::Num(p.num_layers() as f64));
        row.insert(
            "envelope_segments".to_string(),
            Value::Num(p.envelope().num_segments() as f64),
        );
        row.insert("scan_ns".to_string(), Value::Num(scan_ns));
        row.insert("policy_ns".to_string(), Value::Num(policy_ns));
        row.insert("batch_ns_per_decision".to_string(), Value::Num(batch_ns));
        row.insert(
            "scan_decisions_per_s".to_string(),
            Value::Num(1e9 / scan_ns),
        );
        row.insert(
            "policy_decisions_per_s".to_string(),
            Value::Num(1e9 / policy_ns),
        );
        row.insert(
            "batch_decisions_per_s".to_string(),
            Value::Num(1e9 / batch_ns),
        );
        row.insert(
            "speedup_policy_vs_scan".to_string(),
            Value::Num(scan_ns / policy_ns),
        );
        row.insert(
            "speedup_batch_vs_scan".to_string(),
            Value::Num(scan_ns / batch_ns),
        );
        row.insert("slo_scan_ns".to_string(), Value::Num(slo_scan_ns));
        row.insert("slo_envelope_ns".to_string(), Value::Num(slo_envelope_ns));
        row.insert(
            "slo_frontier_len".to_string(),
            Value::Num(slo_policy.slo_partitioner().frontier_len() as f64),
        );
        row.insert(
            "speedup_slo_envelope_vs_scan".to_string(),
            Value::Num(slo_scan_ns / slo_envelope_ns),
        );
        summary.insert(net.name.to_string(), Value::Obj(row));
        println!(
            "  {}: scan {:.0} ns -> policy/envelope {:.0} ns ({:.1}x), batch {:.1} ns/dec ({:.1}x), slo {:.0} -> {:.0} ns ({:.1}x)",
            net.name,
            scan_ns,
            policy_ns,
            scan_ns / policy_ns,
            batch_ns,
            scan_ns / batch_ns,
            slo_scan_ns,
            slo_envelope_ns,
            slo_scan_ns / slo_envelope_ns
        );
    }

    // Offline precomputation (done once per network/model pair); the
    // memoized scheduler makes rebuilds much cheaper than the first build.
    let net = Network::by_name("alexnet").unwrap();
    b.bench("partitioner_build/alexnet", || Partitioner::new(&net, &model));

    // Decision + savings accounting together (the Table-V inner loop).
    let p = Partitioner::new(&net, &model);
    let savings_policy = EnergyPolicy::new(p.clone());
    b.bench("alg2_decide+savings/alexnet", || {
        let d = savings_policy.decide(&DecisionContext::from_sparsity(&p, 0.608, env));
        (d.savings_vs_fcc(), d.savings_vs_fisc())
    });

    // Fleet registry: the per-connection hot path is one read-locked map
    // lookup returning a shared entry; the serialized per-device v2
    // envelope table (energy + latency vectors) is the artifact a
    // coordinator ships to clients.
    let registry = PolicyRegistry::new();
    let entry = registry.get_or_build("alexnet", &env).expect("registry entry");
    let device = device_class(env.p_tx_w);
    let registry_lookup_ns = b
        .bench("registry_lookup/alexnet", || {
            registry.get("alexnet", &device).expect("registered")
        })
        .mean_ns;
    // Two distinct size measurements: the energy-only (v1-shaped) artifact
    // vs the full v2 artifact with its latency tables — the delta is the
    // price of shipping SLO capability to clients.
    let table_bytes =
        EnvelopeTable::from_partitioner("alexnet", &device, env.p_tx_w, entry.partitioner())
            .table_bytes();
    let table_v2_bytes = entry.table().table_bytes();
    assert!(
        entry.table().has_slo_tables(),
        "analytic registry entries must export v2 latency tables"
    );
    assert!(
        table_v2_bytes > table_bytes,
        "v2 artifact must carry more than the energy-only tables"
    );

    // Imported-fleet SLO serving — the PR-5 regression guard: a registry
    // rebuilt purely from the exported JSON must answer SLO decisions from
    // shared (import-reconstructed) engines. If the import ever loses the
    // SLO engine again, serving would regress to per-connection delay
    // -envelope rebuilds — abort the bench (and CI) instead of measuring a
    // lie.
    let client = PolicyRegistry::new();
    let report = client
        .import_json(&registry.export_json())
        .expect("fleet import");
    assert_eq!(
        report.missing_slo, 0,
        "imported v2 fleet lost SLO engines: {report}"
    );
    let imported = client.get("alexnet", &device).expect("imported entry");
    let imported_slo = imported
        .slo_policy()
        .expect("v2 import must reconstruct the shared SLO engine");
    let imported_p = imported.partitioner().clone();
    let mut sp_i = 0.40;
    let mut slo_k = 0;
    let slo_from_import_ns = b
        .bench("slo_from_import/alexnet", || {
            sp_i = if sp_i > 0.9 { 0.40 } else { sp_i + 0.001 };
            slo_k = (slo_k + 1) % SLO_CYCLE_S.len();
            imported_slo.decide(
                &DecisionContext::from_sparsity(&imported_p, sp_i, env)
                    .with_slo(SLO_CYCLE_S[slo_k]),
            )
        })
        .mean_ns;
    println!(
        "  registry: lookup {registry_lookup_ns:.0} ns, table {table_bytes} -> v2 \
         {table_v2_bytes} bytes, imported-fleet slo decision {slo_from_import_ns:.0} ns"
    );

    b.write_csv(std::path::Path::new("results/bench_partitioner.csv"))
        .expect("csv");
    b.write_json(
        std::path::Path::new("results/BENCH_partition.json"),
        vec![
            ("partition".to_string(), Value::Obj(summary)),
            ("batch_size".to_string(), Value::Num(BATCH as f64)),
            ("registry_lookup_ns".to_string(), Value::Num(registry_lookup_ns)),
            ("table_bytes".to_string(), Value::Num(table_bytes as f64)),
            ("table_v2_bytes".to_string(), Value::Num(table_v2_bytes as f64)),
            (
                "slo_from_import_ns".to_string(),
                Value::Num(slo_from_import_ns),
            ),
        ],
    )
    .expect("json");
    println!("wrote results/bench_partitioner.csv and results/BENCH_partition.json");
}
