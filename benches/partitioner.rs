//! Bench: the runtime partition decision (paper Alg. 2) — the O(|L|)
//! linear scan (with its per-call cost-vector allocation) against the
//! precomputed lower-envelope engine and its batched serving path, all
//! through the one public surface, the `PartitionPolicy` trait.
//!
//! The paper's claim is that Alg. 2's overhead is "virtually zero"; the
//! envelope engine makes that literal: `EnergyPolicy::decide` is a
//! breakpoint binary search plus one FCC comparison, and `decide_batch`
//! amortizes the envelope candidates over a whole batch. Emits the
//! criterion-style lines plus `results/bench_partitioner.csv` and the
//! machine-readable `results/BENCH_partition.json` (per-network
//! ns/decision, decisions/s and speedups) so the perf trajectory is
//! tracked across PRs. The registry section measures the fleet surface:
//! shared-entry lookup, v2 artifact size (`table_v2_bytes`) and — the
//! PR-5 regression guard — SLO decisions answered from an **imported**
//! fleet's shared engines (`slo_from_import_ns`): if a v2 import ever
//! stops reconstructing its SLO engine, this bench aborts and CI fails.
//!
//! The lane-kernel section compares the per-item `decide` loop against
//! one `decide_lane_batch` call over reused struct-of-arrays lanes
//! (`decisions_per_sec_scalar` vs `decisions_per_sec_simd`), asserts the
//! kernel wins, and — through a counting global allocator — asserts the
//! steady-state batch loop performs ZERO allocations. The fleet section
//! prices the v3 boot artifact: a 10⁴-entry fleet booted from the binary
//! blob (`fleet_boot_ns`, `v3_blob_bytes`) against a full v2 JSON import
//! (`fleet_import_v2_ns`), asserting the ≥20× boot speedup.
//!
//! Set `NEUPART_BENCH_SMOKE=1` for the CI smoke run (shorter budgets).

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use neupart::bench::Bencher;
use neupart::channel::TransmitEnv;
use neupart::cnn::Network;
use neupart::cnnergy::CnnErgy;
use neupart::partition::{
    decide_with_slo_scan, device_class, BatchLanes, DecisionContext, DelayModel, EnergyPolicy,
    EnvelopeTable, LazyFleet, PartitionPolicy, Partitioner, PolicyRegistry, SloPartitioner,
    SloPolicy, FCC,
};
use neupart::util::json::Value;

const BATCH: usize = 1024;

/// Synthetic fleet size for the v3-boot vs v2-import comparison (the
/// acceptance floor is 10⁴ device classes).
const FLEET_ENTRIES: usize = 10_000;

/// System allocator wrapped in a call counter: the steady-state batch
/// decision loop asserts a ZERO allocation delta, turning any per-call
/// re-allocation regression in the lane kernel into a hard bench
/// failure. Only `alloc`/`realloc` count — frees are irrelevant to the
/// "does the hot loop touch the allocator" question.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// SLO cycle for the constrained benches: loose (unconstrained optimum
/// feasible — the O(log L) hot path), binding (frontier walk), and
/// infeasible (delay-envelope fallback).
const SLO_CYCLE_S: [f64; 3] = [0.5, 0.012, 1e-6];

fn main() {
    let mut b = Bencher::from_env();
    let model = CnnErgy::inference_8bit();
    let env = TransmitEnv::paper_default();

    let mut summary = BTreeMap::new();
    for net in Network::paper_networks() {
        let p = Partitioner::new(&net, &model);
        let policy = EnergyPolicy::new(p.clone());

        // Baseline: the linear scan with a fresh cost vector per decision
        // (`decide_detailed`, the pre-envelope hot path). Sparsity varies
        // per call so the input volume is not branch-predictable.
        let mut sp = 0.40;
        let scan_ns = b
            .bench(&format!("alg2_scan/{}", net.name), || {
                sp = if sp > 0.9 { 0.40 } else { sp + 0.001 };
                policy.decide_detailed(&DecisionContext::from_sparsity(&p, sp, env))
            })
            .mean_ns;

        // Envelope engine through the trait: O(log segments) + one FCC
        // comparison — what the serving coordinator calls. (There is no
        // separate `decide_fast` entry point anymore; the trait path IS
        // the envelope path, so this is the one envelope measurement.)
        let mut sp_p = 0.40;
        let policy_ns = b
            .bench(&format!("policy_decide/{}", net.name), || {
                sp_p = if sp_p > 0.9 { 0.40 } else { sp_p + 0.001 };
                policy.decide(&DecisionContext::from_sparsity(&p, sp_p, env))
            })
            .mean_ns;

        // Batched path: one envelope evaluation per BATCH requests.
        let input_bits: Vec<f64> = (0..BATCH)
            .map(|i| p.transmit_bits(FCC, 0.40 + 0.55 * i as f64 / BATCH as f64))
            .collect();
        let batch_ctx = DecisionContext::from_input_bits(0.0, env);
        let mut out = Vec::with_capacity(BATCH);
        let batch_ns = b
            .bench_elems(
                &format!("alg2_batch{BATCH}/{}", net.name),
                BATCH as u64,
                || {
                    policy.decide_batch(&input_bits, &batch_ctx, &mut out);
                    out.len()
                },
            )
            .mean_ns
            / BATCH as f64;

        // Constrained (SLO) path: the O(|L|) delay scan (fresh delay + cost
        // vectors per call) against the envelope-backed SloPolicy.
        let dm = DelayModel::new(&net, &model);
        let slo_policy = SloPolicy::new(SloPartitioner::new(p.clone(), dm.clone()));
        let mut sp_s = 0.40;
        let mut slo_i = 0;
        let slo_scan_ns = b
            .bench(&format!("slo_scan/{}", net.name), || {
                sp_s = if sp_s > 0.9 { 0.40 } else { sp_s + 0.001 };
                slo_i = (slo_i + 1) % SLO_CYCLE_S.len();
                decide_with_slo_scan(&p, &dm, sp_s, &env, SLO_CYCLE_S[slo_i])
            })
            .mean_ns;
        let mut sp_f = 0.40;
        let mut slo_j = 0;
        let slo_envelope_ns = b
            .bench(&format!("slo_envelope/{}", net.name), || {
                sp_f = if sp_f > 0.9 { 0.40 } else { sp_f + 0.001 };
                slo_j = (slo_j + 1) % SLO_CYCLE_S.len();
                slo_policy.decide(
                    &DecisionContext::from_sparsity(&p, sp_f, env)
                        .with_slo(SLO_CYCLE_S[slo_j]),
                )
            })
            .mean_ns;

        let mut row = BTreeMap::new();
        row.insert("layers".to_string(), Value::Num(p.num_layers() as f64));
        row.insert(
            "envelope_segments".to_string(),
            Value::Num(p.envelope().num_segments() as f64),
        );
        row.insert("scan_ns".to_string(), Value::Num(scan_ns));
        row.insert("policy_ns".to_string(), Value::Num(policy_ns));
        row.insert("batch_ns_per_decision".to_string(), Value::Num(batch_ns));
        row.insert(
            "scan_decisions_per_s".to_string(),
            Value::Num(1e9 / scan_ns),
        );
        row.insert(
            "policy_decisions_per_s".to_string(),
            Value::Num(1e9 / policy_ns),
        );
        row.insert(
            "batch_decisions_per_s".to_string(),
            Value::Num(1e9 / batch_ns),
        );
        row.insert(
            "speedup_policy_vs_scan".to_string(),
            Value::Num(scan_ns / policy_ns),
        );
        row.insert(
            "speedup_batch_vs_scan".to_string(),
            Value::Num(scan_ns / batch_ns),
        );
        row.insert("slo_scan_ns".to_string(), Value::Num(slo_scan_ns));
        row.insert("slo_envelope_ns".to_string(), Value::Num(slo_envelope_ns));
        row.insert(
            "slo_frontier_len".to_string(),
            Value::Num(slo_policy.slo_partitioner().frontier_len() as f64),
        );
        row.insert(
            "speedup_slo_envelope_vs_scan".to_string(),
            Value::Num(slo_scan_ns / slo_envelope_ns),
        );
        summary.insert(net.name.to_string(), Value::Obj(row));
        println!(
            "  {}: scan {:.0} ns -> policy/envelope {:.0} ns ({:.1}x), batch {:.1} ns/dec ({:.1}x), slo {:.0} -> {:.0} ns ({:.1}x)",
            net.name,
            scan_ns,
            policy_ns,
            scan_ns / policy_ns,
            batch_ns,
            scan_ns / batch_ns,
            slo_scan_ns,
            slo_envelope_ns,
            slo_scan_ns / slo_envelope_ns
        );
    }

    // Offline precomputation (done once per network/model pair); the
    // memoized scheduler makes rebuilds much cheaper than the first build.
    let net = Network::by_name("alexnet").unwrap();
    b.bench("partitioner_build/alexnet", || Partitioner::new(&net, &model));

    // Decision + savings accounting together (the Table-V inner loop).
    let p = Partitioner::new(&net, &model);
    let savings_policy = EnergyPolicy::new(p.clone());
    b.bench("alg2_decide+savings/alexnet", || {
        let d = savings_policy.decide(&DecisionContext::from_sparsity(&p, 0.608, env));
        (d.savings_vs_fcc(), d.savings_vs_fisc())
    });

    // Fleet registry: the per-connection hot path is one read-locked map
    // lookup returning a shared entry; the serialized per-device v2
    // envelope table (energy + latency vectors) is the artifact a
    // coordinator ships to clients.
    let registry = PolicyRegistry::new();
    let entry = registry.get_or_build("alexnet", &env).expect("registry entry");
    let device = device_class(env.p_tx_w);
    let registry_lookup_ns = b
        .bench("registry_lookup/alexnet", || {
            registry.get("alexnet", &device).expect("registered")
        })
        .mean_ns;
    // Two distinct size measurements: the energy-only (v1-shaped) artifact
    // vs the full v2 artifact with its latency tables — the delta is the
    // price of shipping SLO capability to clients.
    let table_bytes =
        EnvelopeTable::from_partitioner("alexnet", &device, env.p_tx_w, entry.partitioner())
            .table_bytes();
    let table_v2_bytes = entry.table().table_bytes();
    assert!(
        entry.table().has_slo_tables(),
        "analytic registry entries must export v2 latency tables"
    );
    assert!(
        table_v2_bytes > table_bytes,
        "v2 artifact must carry more than the energy-only tables"
    );

    // Imported-fleet SLO serving — the PR-5 regression guard: a registry
    // rebuilt purely from the exported JSON must answer SLO decisions from
    // shared (import-reconstructed) engines. If the import ever loses the
    // SLO engine again, serving would regress to per-connection delay
    // -envelope rebuilds — abort the bench (and CI) instead of measuring a
    // lie.
    let client = PolicyRegistry::new();
    let report = client
        .import_json(&registry.export_json())
        .expect("fleet import");
    assert_eq!(
        report.missing_slo, 0,
        "imported v2 fleet lost SLO engines: {report}"
    );
    let imported = client.get("alexnet", &device).expect("imported entry");
    let imported_slo = imported
        .slo_policy()
        .expect("v2 import must reconstruct the shared SLO engine");
    let imported_p = imported.partitioner().clone();
    let mut sp_i = 0.40;
    let mut slo_k = 0;
    let slo_from_import_ns = b
        .bench("slo_from_import/alexnet", || {
            sp_i = if sp_i > 0.9 { 0.40 } else { sp_i + 0.001 };
            slo_k = (slo_k + 1) % SLO_CYCLE_S.len();
            imported_slo.decide(
                &DecisionContext::from_sparsity(&imported_p, sp_i, env)
                    .with_slo(SLO_CYCLE_S[slo_k]),
            )
        })
        .mean_ns;
    println!(
        "  registry: lookup {registry_lookup_ns:.0} ns, table {table_bytes} -> v2 \
         {table_v2_bytes} bytes, imported-fleet slo decision {slo_from_import_ns:.0} ns"
    );

    // ---- Lane kernel: per-item decide loop vs decide_lane_batch ----
    // Per-request envs vary both rate and transmit power (a drained
    // γ-lane batch from heterogeneous clients), so neither path gets a
    // branch-predictable γ. Scalar is the per-item trait path the
    // serving coordinator used before the kernel; the batch path is one
    // `decide_lane_batch` call over reused struct-of-arrays lanes
    // (breakpoint counting autovectorizes — `Envelope::segment_index_batch`).
    let lane_envs: Vec<TransmitEnv> = (0..BATCH)
        .map(|i| {
            let f = i as f64 / BATCH as f64;
            TransmitEnv::with_effective_rate(2.0e6 + 148.0e6 * f, 0.5 + f)
        })
        .collect();
    let lane_bits: Vec<f64> = (0..BATCH)
        .map(|i| p.transmit_bits(FCC, 0.40 + 0.55 * i as f64 / BATCH as f64))
        .collect();
    let mut out = Vec::with_capacity(BATCH);
    let scalar_ns = b
        .bench_elems(&format!("lane_scalar{BATCH}/alexnet"), BATCH as u64, || {
            out.clear();
            for (&bits, env) in lane_bits.iter().zip(&lane_envs) {
                out.push(savings_policy.decide(&DecisionContext::from_input_bits(bits, *env)));
            }
            out.len()
        })
        .mean_ns
        / BATCH as f64;
    let mut lanes = BatchLanes::new();
    let lane_ctx = DecisionContext::from_input_bits(0.0, env);
    let simd_ns = b
        .bench_elems(&format!("lane_batch{BATCH}/alexnet"), BATCH as u64, || {
            lanes.clear();
            for (&bits, env) in lane_bits.iter().zip(&lane_envs) {
                lanes.push(bits, *env);
            }
            savings_policy.decide_lane_batch(&mut lanes, &lane_ctx, &mut out);
            out.len()
        })
        .mean_ns
        / BATCH as f64;
    let decisions_per_sec_scalar = 1e9 / scalar_ns;
    let decisions_per_sec_simd = 1e9 / simd_ns;
    assert!(
        decisions_per_sec_simd > decisions_per_sec_scalar,
        "lane-batch kernel must beat the per-item decide loop \
         ({decisions_per_sec_simd:.0}/s vs {decisions_per_sec_scalar:.0}/s)"
    );

    // Steady state must be allocation-free: the lanes and the output
    // vector hold their warmed capacity, and on the envelope path every
    // `Decision` carries empty per-candidate vectors — so the loop below
    // must never touch the allocator. One stray per-call allocation is a
    // regression this bench turns into a hard failure.
    let mut acc = 0.0;
    let allocs_before = ALLOC_CALLS.load(Ordering::Relaxed);
    for _ in 0..64 {
        lanes.clear();
        for (&bits, env) in lane_bits.iter().zip(&lane_envs) {
            lanes.push(bits, *env);
        }
        savings_policy.decide_lane_batch(&mut lanes, &lane_ctx, &mut out);
        acc += out[0].cost_j;
    }
    let steady_allocs = ALLOC_CALLS.load(Ordering::Relaxed) - allocs_before;
    std::hint::black_box(acc);
    assert_eq!(
        steady_allocs, 0,
        "batch decision path allocated {steady_allocs} times across 64 steady-state batches"
    );
    println!(
        "  lane kernel: scalar {scalar_ns:.1} ns/dec -> batch {simd_ns:.1} ns/dec \
         ({:.2}x), 0 steady-state allocations",
        scalar_ns / simd_ns
    );

    // ---- Fleet artifact: 10^4-entry v3 blob boot vs v2 JSON import ----
    // The boot path is the zero-copy claim made literal: open + header
    // and checksum validation is O(blob bytes) streaming work with no
    // per-entry JSON parse and no engine build — entries materialize
    // lazily on first lookup — while the v2 import pays both for every
    // entry up front.
    let author = PolicyRegistry::new();
    for i in 0..FLEET_ENTRIES {
        let mut t = entry.table().clone();
        t.device = format!("synth-{i:05}");
        t.p_tx_w = 0.5 + i as f64 * 1e-4;
        author.insert_table(t);
    }
    assert_eq!(author.len(), FLEET_ENTRIES, "synthetic fleet authoring");
    let v2_json = author.export_json();
    let v3_blob = author.export_v3();
    let v3_blob_bytes = v3_blob.len();

    // v2 import: parse + validate + rebuild engines for every entry.
    // One-shot timing — it sits orders of magnitude above bench noise.
    let t0 = Instant::now();
    let v2_client = PolicyRegistry::new();
    let import_report = v2_client.import_json(&v2_json).expect("v2 fleet import");
    let fleet_import_v2_ns = t0.elapsed().as_nanos() as f64;
    assert_eq!(import_report.imported, FLEET_ENTRIES);

    // v3 boot: validate the blob, leave every entry lazy.
    let blob_arc: Arc<[u8]> = v3_blob.into();
    let fleet_boot_ns = b
        .bench(&format!("fleet_boot_v3/synth{FLEET_ENTRIES}"), || {
            LazyFleet::boot(blob_arc.clone()).expect("fleet boot")
        })
        .mean_ns;
    let fleet = LazyFleet::boot(blob_arc).expect("fleet boot");
    assert_eq!(fleet.blob().len(), FLEET_ENTRIES);
    let booted = fleet
        .get_or_load("alexnet", "synth-00000")
        .expect("lazy load")
        .expect("fleet entry present");
    let eager = v2_client.get("alexnet", "synth-00000").expect("imported entry");
    assert_eq!(
        booted.table(),
        eager.table(),
        "lazy v3 boot and eager v2 import must materialize identical tables"
    );
    let fleet_boot_speedup = fleet_import_v2_ns / fleet_boot_ns;
    assert!(
        fleet_boot_speedup >= 20.0,
        "v3 boot must be >= 20x faster than the v2 JSON import \
         (boot {fleet_boot_ns:.0} ns vs import {fleet_import_v2_ns:.0} ns)"
    );
    println!(
        "  fleet({FLEET_ENTRIES}): v3 blob {v3_blob_bytes} bytes boots in {:.2} ms \
         vs v2 import {:.0} ms -> {fleet_boot_speedup:.0}x",
        fleet_boot_ns / 1e6,
        fleet_import_v2_ns / 1e6
    );

    b.write_csv(std::path::Path::new("results/bench_partitioner.csv"))
        .expect("csv");
    let extras = vec![
        ("partition".to_string(), Value::Obj(summary)),
        ("batch_size".to_string(), Value::Num(BATCH as f64)),
        ("registry_lookup_ns".to_string(), Value::Num(registry_lookup_ns)),
        ("table_bytes".to_string(), Value::Num(table_bytes as f64)),
        ("table_v2_bytes".to_string(), Value::Num(table_v2_bytes as f64)),
        (
            "slo_from_import_ns".to_string(),
            Value::Num(slo_from_import_ns),
        ),
        (
            "decisions_per_sec_scalar".to_string(),
            Value::Num(decisions_per_sec_scalar),
        ),
        (
            "decisions_per_sec_simd".to_string(),
            Value::Num(decisions_per_sec_simd),
        ),
        ("fleet_entries".to_string(), Value::Num(FLEET_ENTRIES as f64)),
        ("fleet_boot_ns".to_string(), Value::Num(fleet_boot_ns)),
        ("fleet_import_v2_ns".to_string(), Value::Num(fleet_import_v2_ns)),
        (
            "fleet_boot_speedup_vs_v2".to_string(),
            Value::Num(fleet_boot_speedup),
        ),
        ("v3_blob_bytes".to_string(), Value::Num(v3_blob_bytes as f64)),
    ];
    // Written twice: under results/ (the CI artifact convention) and at
    // the repo root, where the committed copy records the perf
    // trajectory PR over PR.
    b.write_json(std::path::Path::new("results/BENCH_partition.json"), extras.clone())
        .expect("json");
    b.write_json(std::path::Path::new("BENCH_partition.json"), extras)
        .expect("json");
    println!(
        "wrote results/bench_partitioner.csv, results/BENCH_partition.json \
         and BENCH_partition.json"
    );
}
