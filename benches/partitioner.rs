//! Bench: the runtime partition decision (paper Alg. 2).
//!
//! The paper's claim: Alg. 2 is "computationally very cheap … the overhead
//! of running it is virtually zero" — O(|L|) flops. Target: well under a
//! microsecond per decision for every network.

use neupart::bench::Bencher;
use neupart::channel::TransmitEnv;
use neupart::cnn::Network;
use neupart::cnnergy::CnnErgy;
use neupart::partition::Partitioner;

fn main() {
    let mut b = Bencher::default();
    let model = CnnErgy::inference_8bit();
    let env = TransmitEnv::paper_default();

    for net in Network::paper_networks() {
        let p = Partitioner::new(&net, &model);
        let mut sp = 0.40;
        b.bench(&format!("alg2_decide/{}", net.name), || {
            sp = if sp > 0.9 { 0.40 } else { sp + 0.001 };
            p.decide(sp, &env)
        });
    }

    // Offline precomputation (done once per network/model pair).
    let net = Network::by_name("alexnet").unwrap();
    b.bench("partitioner_build/alexnet", || Partitioner::new(&net, &model));

    // Decision + savings accounting together.
    let p = Partitioner::new(&net, &model);
    b.bench("alg2_decide+savings/alexnet", || {
        let d = p.decide(0.608, &env);
        (d.savings_vs_fcc(), d.savings_vs_fisc())
    });

    b.write_csv(std::path::Path::new("results/bench_partitioner.csv"))
        .expect("csv");
}
