//! Bench: the CNNergy analytical model (paper Alg. 1 + §IV-C scheduler)
//! and the compiled-profile layer on top of it.
//!
//! CNNergy runs offline in NeuPart, but as an open-sourced simulator its
//! own cost matters: engine *builds* and design-space *sweeps* re-evaluate
//! the model thousands of times. This bench tracks the compile-then-query
//! flow end to end:
//!
//! * `profile_build` — one-pass [`NetworkProfile`] compile (the §IV model
//!   evaluated once per (network, hardware) point).
//! * `engine_build_fresh` vs `engine_build_from_profile` — the complete
//!   engine stack (partitioner + delay model + SLO engine) built for every
//!   paper network, fresh (two full model evaluations per network, the
//!   pre-profile path) against sliced from precompiled profiles; both
//!   sides run the same envelope/frontier construction, so the ratio
//!   isolates the avoided model re-evaluation. The raw partitioner slice
//!   is `partitioner_from_profile`, and the shipped warm-registry
//!   per-connection hit is reported honestly as `registry_entry_lookup`.
//! * `glb_sweep_rebuild` vs `glb_sweep_incremental` — the Fig. 14(c) GLB
//!   sweep as a full model rebuild per point against the incremental
//!   profile path (`NetworkProfile::with_glb_size` through the keyed
//!   profile cache).
//! * `profile_sweep_serial` vs `profile_sweep_parallel` — cold profile
//!   compiles over (network × GLB) grids, serial loop vs the scoped-thread
//!   parallel sweep driver (`util::par::par_map`). A fresh GLB offset per
//!   iteration keeps both paths cold (no memoized schedules), so the ratio
//!   is the driver's honest speedup.
//!
//! Emits `results/bench_cnnergy.csv` plus the machine-readable
//! `results/BENCH_cnnergy.json` (`profile_build_ns`,
//! `engine_build_from_profile_ns`, `sweep_rebuild_ns`,
//! `sweep_incremental_ns`, `parallel_sweep_speedup`, …) so the build/sweep
//! perf trajectory is tracked across PRs; CI asserts the keys exist and
//! that the incremental sweep stays faster than the rebuild sweep. Set
//! `NEUPART_BENCH_SMOKE=1` for the CI smoke run (shorter budgets).

use neupart::bench::Bencher;
use neupart::channel::TransmitEnv;
use neupart::cnn::{ConvShape, Network};
use neupart::cnnergy::{global_profiles, schedule, CnnErgy, HwConfig, NetworkProfile};
use neupart::partition::{DelayModel, Partitioner, PolicyRegistry, SloPartitioner};
use neupart::util::json::Value;
use neupart::util::par::par_map;

fn main() {
    let mut b = Bencher::from_env();
    let hw = HwConfig::eyeriss_8bit();

    // The scheduling mapper on representative layer shapes.
    for (name, shape) in [
        ("alexnet_c1", ConvShape::conv(227, 227, 11, 3, 96, 4)),
        ("alexnet_c3", ConvShape::conv(15, 15, 3, 256, 384, 1)),
        ("vgg_c4_2", ConvShape::conv(30, 30, 3, 512, 512, 1)),
        ("squeeze_fs9_1x1", ConvShape::conv(14, 14, 1, 512, 64, 1)),
        ("fc6", ConvShape::fc(6, 6, 256, 4096)),
    ] {
        b.bench(&format!("schedule/{name}"), || schedule(&shape, &hw));
    }

    // Whole-network energy evaluation (the design-space inner loop).
    let model = CnnErgy::inference_8bit();
    for net in Network::paper_networks() {
        b.bench(&format!("network_energy/{}", net.name), || {
            model.total_energy_pj(&net)
        });
    }

    let net = Network::by_name("alexnet").unwrap();
    let nets = Network::paper_networks();

    // One-pass profile compile at steady state: the thread-local §IV-C
    // mapper cache is warm after the first iteration, so this is the
    // repeated-build cost (what engine rebuilds used to pay per call); the
    // true cold-compile cost, mapper derivation included, is what the
    // sweep benches below measure (fresh hardware point per iteration).
    let profile_build_ns = b
        .bench("profile_build_warm_mapper/alexnet", || {
            NetworkProfile::compute(&net, &model)
        })
        .mean_ns;

    // Engine-stack builds over ALL paper networks (a fleet's cold start):
    // fresh rebuild — the pre-profile path, two full model evaluations per
    // network (partitioner + delay model) plus the SLO construction —
    // against the same stack sliced from precompiled profiles. Both sides
    // construct the complete SloPartitioner; only the model re-evaluation
    // differs, so the ratio is the honest table-slicing win.
    let engine_build_fresh_ns = b
        .bench("engine_build_fresh/paper_nets", || {
            nets.iter()
                .map(|n| {
                    SloPartitioner::new(Partitioner::new(n, &model), DelayModel::new(n, &model))
                        .frontier_len()
                })
                .sum::<usize>()
        })
        .mean_ns;
    let profiles: Vec<_> = nets.iter().map(|n| model.compiled(n)).collect();
    let engine_build_from_profile_ns = b
        .bench("engine_build_from_profile/paper_nets", || {
            profiles
                .iter()
                .map(|p| {
                    SloPartitioner::new(
                        Partitioner::from_profile(p),
                        DelayModel::from_profile(p),
                    )
                    .frontier_len()
                })
                .sum::<usize>()
        })
        .mean_ns;

    // Raw table slicing from the compiled profile, alone.
    let profile = model.compiled(&net);
    let partitioner_from_profile_ns = b
        .bench("partitioner_from_profile/alexnet", || {
            Partitioner::from_profile(&profile)
        })
        .mean_ns;

    // The shipped per-connection acquisition path — the profile-backed
    // registry hands back already-built shared engines; this is a warm map
    // hit plus `Arc` clones, reported under its own (honest) key.
    let env = TransmitEnv::paper_default();
    let registry = PolicyRegistry::new();
    registry.get_or_build("alexnet", &env).expect("registry entry");
    let registry_entry_lookup_ns = b
        .bench("registry_entry_lookup/alexnet", || {
            let entry = registry.get_or_build("alexnet", &env).expect("entry");
            assert!(entry.slo_partitioner().is_some());
            entry
        })
        .mean_ns;

    // Fig. 14(c) GLB sweep, full model rebuild per point (legacy path).
    let glb_kbs = [8usize, 16, 32, 48, 64, 88, 108, 128, 256, 512];
    let sweep_rebuild_ns = b
        .bench("glb_sweep_rebuild10/alexnet", || {
            let mut acc = 0.0;
            for &kb in &glb_kbs {
                acc += CnnErgy::inference_8bit()
                    .with_glb_size(kb * 1024)
                    .total_energy_pj(&net);
            }
            acc
        })
        .mean_ns;

    // Same sweep through the incremental profile path (keyed cache +
    // reused volume tables) — what fig14c now runs.
    let base = model.compiled(&net);
    let sweep_incremental_ns = b
        .bench("glb_sweep_incremental10/alexnet", || {
            let mut acc = 0.0;
            for &kb in &glb_kbs {
                acc += base.with_glb_size(kb * 1024).total_energy_pj();
            }
            acc
        })
        .mean_ns;

    // Parallel sweep driver vs a serial loop on cold profile compiles.
    // Each iteration uses a fresh byte-scale GLB offset so every point
    // derives its own schedules on both paths (no memoization); serial
    // takes even epochs and parallel odd ones — disjoint keys, identical
    // size scale, so the two sides run the same workload.
    let grid: Vec<(usize, usize)> = (0..nets.len())
        .flat_map(|i| [8usize, 32, 88, 128, 512].map(move |kb| (i, kb)))
        .collect();
    let mut epoch_serial = 0usize;
    let sweep_serial_ns = b
        .bench("profile_sweep_serial20/paper_nets", || {
            epoch_serial += 2;
            let mut acc = 0.0;
            for &(i, kb) in &grid {
                let point = CnnErgy::inference_8bit().with_glb_size(kb * 1024 + epoch_serial);
                acc += NetworkProfile::compute(&nets[i], &point).total_energy_pj();
            }
            acc
        })
        .mean_ns;
    let mut epoch_parallel = 1usize;
    let sweep_parallel_ns = b
        .bench("profile_sweep_parallel20/paper_nets", || {
            epoch_parallel += 2;
            let sized: Vec<(usize, usize)> = grid
                .iter()
                .map(|&(i, kb)| (i, kb * 1024 + epoch_parallel))
                .collect();
            par_map(&sized, |&(i, glb)| {
                let point = CnnErgy::inference_8bit().with_glb_size(glb);
                NetworkProfile::compute(&nets[i], &point).total_energy_pj()
            })
            .into_iter()
            .sum::<f64>()
        })
        .mean_ns;

    println!(
        "  profile: build {profile_build_ns:.0} ns; engine fresh {engine_build_fresh_ns:.0} ns \
         -> from profile {engine_build_from_profile_ns:.0} ns ({:.1}x); GLB sweep rebuild \
         {sweep_rebuild_ns:.0} ns -> incremental {sweep_incremental_ns:.0} ns ({:.1}x); \
         parallel driver {:.1}x",
        engine_build_fresh_ns / engine_build_from_profile_ns,
        sweep_rebuild_ns / sweep_incremental_ns,
        sweep_serial_ns / sweep_parallel_ns
    );

    b.write_csv(std::path::Path::new("results/bench_cnnergy.csv"))
        .expect("csv");
    let mut cache = std::collections::BTreeMap::new();
    cache.insert(
        "hits".to_string(),
        Value::Num(global_profiles().hits() as f64),
    );
    cache.insert(
        "misses".to_string(),
        Value::Num(global_profiles().misses() as f64),
    );
    cache.insert(
        "entries".to_string(),
        Value::Num(global_profiles().len() as f64),
    );
    b.write_json(
        std::path::Path::new("results/BENCH_cnnergy.json"),
        vec![
            ("profile_build_ns".to_string(), Value::Num(profile_build_ns)),
            (
                "partitioner_from_profile_ns".to_string(),
                Value::Num(partitioner_from_profile_ns),
            ),
            (
                "engine_build_fresh_ns".to_string(),
                Value::Num(engine_build_fresh_ns),
            ),
            (
                "engine_build_from_profile_ns".to_string(),
                Value::Num(engine_build_from_profile_ns),
            ),
            (
                "speedup_engine_build".to_string(),
                Value::Num(engine_build_fresh_ns / engine_build_from_profile_ns),
            ),
            (
                "registry_entry_lookup_ns".to_string(),
                Value::Num(registry_entry_lookup_ns),
            ),
            ("sweep_rebuild_ns".to_string(), Value::Num(sweep_rebuild_ns)),
            (
                "sweep_incremental_ns".to_string(),
                Value::Num(sweep_incremental_ns),
            ),
            (
                "speedup_sweep_incremental".to_string(),
                Value::Num(sweep_rebuild_ns / sweep_incremental_ns),
            ),
            ("sweep_serial_ns".to_string(), Value::Num(sweep_serial_ns)),
            ("sweep_parallel_ns".to_string(), Value::Num(sweep_parallel_ns)),
            (
                "parallel_sweep_speedup".to_string(),
                Value::Num(sweep_serial_ns / sweep_parallel_ns),
            ),
            ("profile_cache".to_string(), Value::Obj(cache)),
        ],
    )
    .expect("json");
    println!("wrote results/bench_cnnergy.csv and results/BENCH_cnnergy.json");
}
