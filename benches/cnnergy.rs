//! Bench: the CNNergy analytical model (paper Alg. 1 + §IV-C scheduler).
//!
//! These run offline in NeuPart, but as an open-sourced simulator CNNergy's
//! own cost matters for design-space sweeps (thousands of evaluations).

use neupart::bench::Bencher;
use neupart::cnn::{ConvShape, Network};
use neupart::cnnergy::{schedule, CnnErgy, HwConfig};

fn main() {
    let mut b = Bencher::default();
    let hw = HwConfig::eyeriss_8bit();

    // The scheduling mapper on representative layer shapes.
    for (name, shape) in [
        ("alexnet_c1", ConvShape::conv(227, 227, 11, 3, 96, 4)),
        ("alexnet_c3", ConvShape::conv(15, 15, 3, 256, 384, 1)),
        ("vgg_c4_2", ConvShape::conv(30, 30, 3, 512, 512, 1)),
        ("squeeze_fs9_1x1", ConvShape::conv(14, 14, 1, 512, 64, 1)),
        ("fc6", ConvShape::fc(6, 6, 256, 4096)),
    ] {
        b.bench(&format!("schedule/{name}"), || schedule(&shape, &hw));
    }

    // Whole-network energy evaluation (the design-space inner loop).
    let model = CnnErgy::inference_8bit();
    for net in Network::paper_networks() {
        b.bench(&format!("network_energy/{}", net.name), || {
            model.total_energy_pj(&net)
        });
    }

    // A full GLB design sweep (paper Fig. 14(c)) as one unit.
    let net = Network::by_name("alexnet").unwrap();
    b.bench("glb_sweep_10pts/alexnet", || {
        let mut acc = 0.0;
        for kb in [8usize, 16, 32, 48, 64, 88, 108, 128, 256, 512] {
            acc += CnnErgy::inference_8bit()
                .with_glb_size(kb * 1024)
                .total_energy_pj(&net);
        }
        acc
    });

    b.write_csv(std::path::Path::new("results/bench_cnnergy.csv"))
        .expect("csv");
}
