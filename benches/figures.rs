//! Bench: regeneration cost of every paper table/figure — the harness a
//! user runs after modifying the model. Each experiment is timed once
//! (they are deterministic); the cheap analytic ones are also iterated.

use std::path::Path;
use std::time::Instant;

use neupart::bench::Bencher;
use neupart::experiments;

fn main() {
    let out = Path::new("results/bench_figures_out");
    println!("one-shot regeneration wall times:");
    for id in experiments::ALL {
        let t0 = Instant::now();
        experiments::run(id, out).expect(id);
        println!("  {id:<8} {:>9.1} ms", t0.elapsed().as_secs_f64() * 1e3);
    }

    let mut b = Bencher::quick();
    for id in ["fig2", "fig8b", "fig11", "fig14b", "fig14c"] {
        b.bench(&format!("regen/{id}"), || experiments::run(id, out).unwrap());
    }
    b.write_csv(Path::new("results/bench_figures.csv")).expect("csv");
}
