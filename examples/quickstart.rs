//! Quickstart: the NeuPart public API in ~40 lines.
//!
//! Builds the CNNergy model, asks for AlexNet's per-layer energy, and makes
//! a runtime partition decision for a concrete communication environment —
//! the library's two core calls.
//!
//! Run: `cargo run --release --example quickstart`

use neupart::channel::TransmitEnv;
use neupart::cnn::Network;
use neupart::cnnergy::CnnErgy;
use neupart::partition::{DecisionContext, EnergyPolicy, PartitionPolicy, Partitioner};

fn main() {
    // 1. An analytical energy model for an Eyeriss-class accelerator at the
    //    paper's 8-bit inference operating point (§VIII).
    let model = CnnErgy::inference_8bit();

    // 2. A network topology (AlexNet; also squeezenet / googlenet / vgg16).
    let net = Network::by_name("alexnet").unwrap();

    // 3. Per-layer cumulative client energy E_L (paper eq. 2).
    let cumulative = model.cumulative_energy_pj(&net);
    println!("E_L (cumulative client energy):");
    for (layer, e) in net.layers.iter().zip(&cumulative) {
        println!("  up to {:<4} {:>8.3} mJ", layer.name, e * 1e-9);
    }

    // 4. The decision policy (Alg. 2): the partitioner precomputes
    //    everything offline, the policy is the decision surface…
    let policy = EnergyPolicy::new(Partitioner::new(&net, &model));

    // 5. …then decides per image, given the probed JPEG sparsity and the
    //    current communication environment.
    let env = TransmitEnv {
        bit_rate_bps: 88.0e6, // B
        ecc_percent: 10.0,    // k  -> B_e = 80 Mbps
        p_tx_w: 0.78,         // LG Nexus 4 WLAN (Table IV)
    };
    // Median Sparsity-In; the hot-path `decide` carries the full energy
    // accounting (use `decide_detailed` for the per-candidate vector).
    let ctx = DecisionContext::from_sparsity(policy.partitioner(), 0.608, env);
    let decision = policy.decide(&ctx);

    let optimal = if decision.l_opt == 0 {
        "In (fully cloud)"
    } else if decision.l_opt == net.num_layers() {
        "output (fully in situ)"
    } else {
        net.layers[decision.l_opt - 1].name
    };
    println!("\noptimal partition: {optimal}");
    println!(
        "E_cost {:.3} mJ = client {:.3} mJ + radio {:.3} mJ",
        decision.cost_j * 1e3,
        decision.client_energy_j * 1e3,
        decision.transmit_energy_j * 1e3
    );
    println!(
        "saves {:.1}% vs fully-cloud and {:.1}% vs fully-on-device",
        decision.savings_vs_fcc() * 100.0,
        decision.savings_vs_fisc() * 100.0
    );
}
