//! Domain scenario from the paper's introduction: a health worker in a
//! remote area runs skin-lesion classification on a battery-limited phone
//! with a weak, variable uplink (paper §I-A, [3]).
//!
//! Simulates a day in the field: the uplink quality drifts between 2G-ish
//! and good WLAN rates; for each captured image NeuPart re-decides the
//! partition with the *current* bandwidth, and we track battery drain vs
//! the static FCC / FISC policies.
//!
//! Run: `cargo run --release --example field_clinic`

use neupart::channel::TransmitEnv;
use neupart::cnn::Network;
use neupart::cnnergy::CnnErgy;
use neupart::compress::jpeg::compress_rgb;
use neupart::corpus::Corpus;
use neupart::partition::{DecisionContext, EnergyPolicy, PartitionPolicy, Partitioner};
use neupart::util::rng::Rng;

/// A phone battery in joules (≈ 3000 mAh at 3.8 V ≈ 41 kJ; we track the
/// fraction the CNN workload consumes).
const BATTERY_J: f64 = 41_000.0;

fn main() {
    let net = Network::by_name("squeezenet").unwrap(); // mobile-class CNN
    let model = CnnErgy::inference_8bit();
    let policy = EnergyPolicy::new(Partitioner::new(&net, &model));
    let corpus = Corpus::imagenet_like(99);
    let mut rng = Rng::new(2026);

    let captures = 200; // images captured over the day
    let mut e_neupart = 0.0;
    let mut e_fcc = 0.0;
    let mut e_fisc = 0.0;
    let mut splits = std::collections::BTreeMap::<String, u32>::new();

    println!("field clinic: {captures} diagnoses on {}, drifting uplink\n", net.name);
    for i in 0..captures {
        // Bandwidth drifts through the day: 1..120 Mbps, lognormal-ish.
        let drift = (rng.next_gaussian() * 0.9).exp();
        let be_mbps = (12.0 * drift).clamp(1.0, 120.0);
        let env = TransmitEnv::with_effective_rate(be_mbps * 1e6, 0.78);

        let img = corpus.image(i);
        let probe = compress_rgb(&img.pixels, img.w, img.h, 90);

        let ctx = DecisionContext::from_sparsity(policy.partitioner(), probe.sparsity, env);
        let d = policy.decide(&ctx);
        e_neupart += d.cost_j;
        e_fcc += d.fcc_cost_j;
        e_fisc += d.fisc_cost_j;
        let name = if d.l_opt == 0 {
            "In".to_string()
        } else {
            net.layers[d.l_opt - 1].name.to_string()
        };
        *splits.entry(name).or_insert(0) += 1;

        if i % 40 == 0 {
            println!(
                "  capture {i:>3}: Be {be_mbps:>6.1} Mbps, Sparsity-In {:>5.1}% -> split {}",
                probe.sparsity * 100.0,
                if d.l_opt == 0 { "In" } else { net.layers[d.l_opt - 1].name }
            );
        }
    }

    println!("\nchosen splits over the day: {splits:?}");
    println!("\nclient energy for the day's workload:");
    for (label, e) in [("NeuPart", e_neupart), ("FCC", e_fcc), ("FISC", e_fisc)] {
        println!(
            "  {label:<8} {:>8.1} mJ  ({:.4}% of battery)",
            e * 1e3,
            e / BATTERY_J * 100.0
        );
    }
    println!(
        "\nNeuPart extends the CNN-workload battery budget {:.2}x over FCC, {:.2}x over FISC",
        e_fcc / e_neupart,
        e_fisc / e_neupart
    );
}
