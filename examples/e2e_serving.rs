//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Loads the AOT-compiled `tiny_alexnet` artifacts (Pallas conv kernels →
//! JAX model → HLO → PJRT), starts the client/cloud serving coordinator,
//! and serves batched image requests from the synthetic corpus under three
//! policies — NeuPart (runtime Alg. 2), forced-FCC, forced-FISC — reporting
//! per-policy client energy, latency and throughput, and verifying that
//! partitioned inference agrees with cloud-only inference.
//!
//! Requires `make artifacts` first. Results recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo run --release --example e2e_serving [-- requests=64]`

use std::path::PathBuf;

use neupart::channel::TransmitEnv;
use neupart::coordinator::{
    Coordinator, CoordinatorConfig, ExecutorBackend, HealthConfig, InferenceRequest, RetryPolicy,
};
use neupart::corpus::Corpus;

fn requests(n: usize, seed: u64) -> Vec<InferenceRequest> {
    let corpus = Corpus::new(32, 32, seed);
    corpus
        .iter(n)
        .enumerate()
        .map(|(i, img)| {
            InferenceRequest::new(i as u64, img.to_f32_nhwc(), img.pixels, img.w, img.h)
        })
        .collect()
}

fn config(force_split: Option<usize>, be_mbps: f64) -> CoordinatorConfig {
    let warm_splits = match force_split {
        Some(s) => vec![s],
        None => (0..=11).collect(),
    };
    CoordinatorConfig {
        artifacts_dir: PathBuf::from("artifacts"),
        network: "tiny_alexnet".to_string(),
        env: TransmitEnv::with_effective_rate(be_mbps * 1e6, 0.78),
        jpeg_quality: 90,
        cloud_pool: 2,
        workers: 4,
        jitter: 0.0,
        time_scale: 0.0,
        force_split,
        warm_splits,
        batch_max: 8,
        gamma_coherent: true,
        shed_infeasible: true,
        backend: ExecutorBackend::Pjrt,
        faults: None,
        scenario: None,
        redecide: None,
        retry: RetryPolicy::default(),
        health: HealthConfig::default(),
        seed: 7,
    }
}

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .find_map(|a| a.strip_prefix("requests=").map(|v| v.parse().unwrap()))
        .unwrap_or(48);

    // The tiny client accelerator's FCC/FISC crossover sits near 130 Mbps
    // (its conv layers dominate energy, so — honestly, unlike full AlexNet —
    // there is no wide intermediate band; see EXPERIMENTS.md §E2E). Serving
    // at the crossover makes the per-image Sparsity-In probe decide each
    // request individually, exactly the paper's runtime scenario.
    let be = 130.0;
    println!("== NeuPart end-to-end serving: tiny_alexnet, {n} requests, Be = {be} Mbps ==\n");

    let mut summary = Vec::new();
    let mut reference_top1: Vec<usize> = Vec::new();
    for (label, force) in [
        ("FCC (all cloud)", Some(0usize)),
        ("FISC (all client)", Some(11usize)),
        ("NeuPart (Alg. 2)", None),
    ] {
        // Coordinator::new blocks until every executor thread has compiled
        // its warm_splits, so the serve below measures steady state.
        let t_init = std::time::Instant::now();
        let coord = Coordinator::new(config(force, be))?;
        println!("  [{label}] startup (artifact compile): {:.1} s", t_init.elapsed().as_secs_f64());
        let reqs = requests(n, 7);
        let t0 = std::time::Instant::now();
        let responses = coord.serve_responses(reqs)?;
        let wall = t0.elapsed();

        // Verify numerics: every policy must classify like the cloud does.
        let top1: Vec<usize> = responses.iter().map(|r| r.top1()).collect();
        if reference_top1.is_empty() {
            reference_top1 = top1.clone();
        } else {
            let agree = top1
                .iter()
                .zip(&reference_top1)
                .filter(|(a, b)| a == b)
                .count();
            println!(
                "  [{label}] top-1 agreement with FCC: {agree}/{n} ({:.0}%)",
                agree as f64 / n as f64 * 100.0
            );
            assert!(
                agree as f64 >= n as f64 * 0.9,
                "partitioned inference diverged from cloud inference"
            );
        }

        let m = coord.metrics.snapshot();
        println!("--- {label} ---\n{}", m.report());
        println!(
            "  wall {:.2} s -> {:.1} req/s\n",
            wall.as_secs_f64(),
            n as f64 / wall.as_secs_f64()
        );
        summary.push((label, m.mean_e_cost_j() * 1e3, wall.as_secs_f64()));
    }

    println!("== summary (client-side energy per inference) ==");
    for (label, e_mj, wall) in &summary {
        println!("  {label:<20} {e_mj:>8.4} mJ   ({wall:.2} s wall)");
    }
    let neupart = summary[2].1;
    let fcc = summary[0].1;
    let fisc = summary[1].1;
    println!(
        "\nNeuPart saves {:.1}% vs FCC and {:.1}% vs FISC on this workload",
        (1.0 - neupart / fcc) * 100.0,
        (1.0 - neupart / fisc) * 100.0
    );
    Ok(())
}
