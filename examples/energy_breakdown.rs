//! CNNergy as a design tool: per-component energy breakdowns and the
//! customized energy access the paper highlights (§I-B) — data-access
//! energy per memory level, MAC energy, control split — plus a GLB
//! design-space sweep (paper Fig. 14(c)).
//!
//! Run: `cargo run --release --example energy_breakdown [network]`

use neupart::cnn::Network;
use neupart::cnnergy::CnnErgy;

fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "squeezenet".into());
    let net = Network::by_name(&name).unwrap_or_else(|| {
        eprintln!("unknown network {name}");
        std::process::exit(1);
    });
    let model = CnnErgy::inference_8bit();
    let breakdowns = model.network_breakdowns(&net);

    println!("{} — component breakdown (µJ, 8-bit):", net.name);
    println!(
        "{:<7} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}",
        "layer", "MAC", "RF", "GLB", "DRAM", "clock", "other"
    );
    let mut totals = [0.0f64; 6];
    for (layer, e) in net.layers.iter().zip(&breakdowns) {
        let row = [e.comp, e.rf + e.inter_pe, e.glb, e.dram, e.cntrl_clk, e.cntrl_other];
        for (t, v) in totals.iter_mut().zip(row) {
            *t += v;
        }
        println!(
            "{:<7} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            layer.name,
            row[0] * 1e-6,
            row[1] * 1e-6,
            row[2] * 1e-6,
            row[3] * 1e-6,
            row[4] * 1e-6,
            row[5] * 1e-6
        );
    }
    let grand: f64 = totals.iter().sum();
    println!(
        "\nshares: MAC {:.1}%  RF {:.1}%  GLB {:.1}%  DRAM {:.1}%  clock {:.1}%  other {:.1}%",
        totals[0] / grand * 100.0,
        totals[1] / grand * 100.0,
        totals[2] / grand * 100.0,
        totals[3] / grand * 100.0,
        totals[4] / grand * 100.0,
        totals[5] / grand * 100.0
    );

    // Design-space exploration: how does total energy move with GLB size?
    println!("\nGLB design sweep (paper Fig. 14(c)):");
    for kb in [8usize, 16, 32, 64, 88, 108, 128, 256, 512] {
        let m = CnnErgy::inference_8bit().with_glb_size(kb * 1024);
        println!("  GLB {kb:>4} kB -> {:.3} mJ", m.total_energy_pj(&net) * 1e-9);
    }
}
