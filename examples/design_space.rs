//! Design-space exploration with CNNergy (paper §VIII-B): sweep accelerator
//! parameters — GLB size, PE-array shape, RF sizes, bit width — and report
//! total AlexNet inference energy for each point. This is the "energy model
//! as a design tool" use case the paper open-sourced CNNergy for.
//!
//! Run: `cargo run --release --example design_space`

use neupart::cnn::alexnet;
use neupart::cnnergy::{CnnErgy, HwConfig, TechParams};
use neupart::util::par::par_map;

fn total_mj(model: &CnnErgy) -> f64 {
    model.total_energy_pj(&alexnet()) * 1e-9
}

fn main() {
    let net = alexnet();
    println!("design-space exploration on {} (total inference energy)\n", net.name);

    // 1. GLB size (paper Fig. 14(c)) — the incremental profile path: one
    // compiled base profile, each point re-derives only the GLB-dependent
    // terms, and the points run under the parallel sweep driver.
    println!("GLB size sweep:");
    let base = CnnErgy::inference_8bit().compiled(&net);
    let glb_kbs = [8usize, 16, 32, 64, 88, 108, 128, 256];
    let totals = par_map(&glb_kbs, |&kb| {
        base.with_glb_size(kb * 1024).total_energy_pj() * 1e-9
    });
    for (kb, mj) in glb_kbs.iter().zip(totals) {
        println!("  {kb:>4} kB          -> {mj:.3} mJ");
    }

    // 2. PE-array shape at constant PE count (168 PEs).
    println!("\nPE-array shape sweep (168 PEs):");
    for (j, k) in [(6, 28), (12, 14), (14, 12), (24, 7), (28, 6)] {
        let mut hw = HwConfig::eyeriss_8bit();
        hw.j = j;
        hw.k = k;
        let model = CnnErgy {
            hw,
            ..CnnErgy::inference_8bit()
        };
        println!("  {j:>2} x {k:<2}          -> {:.3} mJ", total_mj(&model));
    }

    // 3. Ifmap RF size (drives z_i, the channels per pass).
    println!("\nifmap RF size sweep:");
    for i_s in [6usize, 12, 24, 48, 96] {
        let mut hw = HwConfig::eyeriss_8bit();
        hw.i_s = i_s * 2; // 8-bit packing
        let model = CnnErgy {
            hw,
            ..CnnErgy::inference_8bit()
        };
        println!("  I_s = {i_s:>3} words  -> {:.3} mJ", total_mj(&model));
    }

    // 4. Arithmetic bit width (quadratic multiply / linear memory scaling).
    println!("\nbit-width sweep:");
    for bits in [4u32, 8, 12, 16] {
        let mut hw = HwConfig::eyeriss();
        hw.b_w = bits;
        let scale = 16 / bits as usize;
        hw.f_s *= scale.max(1);
        hw.i_s *= scale.max(1);
        hw.p_s *= scale.max(1);
        let model = CnnErgy {
            hw,
            tech: TechParams::at_bits(bits),
            glb_energy: TechParams::at_bits(bits).e_glb,
            ..CnnErgy::inference_8bit()
        };
        println!("  {bits:>2}-bit          -> {:.3} mJ", total_mj(&model));
    }

    println!(
        "\n(GLB points slice the compiled profile incrementally; the other \
         sweeps re-run the §IV-C scheduler per hardware point)"
    );
}
